//! The paper's §4 hybrid algorithm: conv layers train on the browser
//! clients, the FC block trains on the server, *concurrently*.
//!
//! One round, as implemented here (server side):
//!
//! 1. publish this round's conv parameters as a round dataset
//!    (`<net>_convp_r<round>`) — every client fetches the blob once and
//!    caches it, like the paper's browsers cache external files;
//! 2. submit one `conv_fwd` ticket per shard; clients run the conv stack
//!    forward and upload the boundary features;
//! 3. as each feature batch arrives, run the `<net>_fc_step` artifact:
//!    one AdaGrad-β step on the FC block that also emits the boundary
//!    cotangent `dL/dfeat`, which goes straight back out as that shard's
//!    `conv_grad` ticket (the client recomputes the conv forward instead
//!    of shipping activations — DESIGN.md §6.1);
//! 4. while waiting on slow links, keep the server busy with **bounded
//!    replay**: extra FC steps on cached feature batches from earlier
//!    arrivals (at most [`HybridConfig::max_replay_per_round`] per
//!    round).  This is why the paper's FC line sits above 1× stand-alone
//!    while the conv line scales with clients (Fig 5);
//! 5. when every shard's conv gradients are back, apply their
//!    sample-weighted mean ([`crate::dist::aggregate_gradients`]) to the
//!    conv parameters with native AdaGrad-β and start the next round.
//!
//! Fault tolerance is inherited: tickets lost to killed clients are
//! redistributed by the store's virtual-created-time policy, and
//! first-result-wins deduplicates stragglers.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::dist::{aggregate_gradients, Cluster, DistStats, TrainResult};
use crate::nn::adagrad;
use crate::nn::metrics::Curve;
use crate::nn::params::ParamSet;
use crate::runtime::{SharedRuntime, Tensor};
use crate::store::Scheduler as _;
use crate::tasks::tensor_from_json;
use crate::tasks::train::{
    pack_params, params_key, shard_x_key, shard_y_key, unflatten, ConvFwdTask, ConvGradTask,
};
use crate::util::clock::PaddedTimer;
use crate::util::rng::SplitMix64;

/// Knobs of the hybrid trainer.
#[derive(Debug, Clone)]
pub struct HybridConfig {
    /// Number of rounds (one conv batch per shard per round).
    pub rounds: u64,
    /// Seed for the parameter init (the loss trajectory is reproducible
    /// up to completion-arrival order, which only permutes commutative
    /// gradient sums and FC-step order).
    pub seed: u64,
    /// Cap on replay FC steps per round (0 disables replay).
    pub max_replay_per_round: u64,
    /// How long one completion poll waits before the server considers a
    /// replay step instead, ms.
    pub poll_ms: u64,
    /// Modelled server device speed (the Fig 5 fleet pads the server
    /// exactly like the clients); `f64::INFINITY` = host speed.
    pub server_speed: f64,
}

impl Default for HybridConfig {
    fn default() -> Self {
        HybridConfig {
            rounds: 4,
            seed: 42,
            max_replay_per_round: 8,
            poll_ms: 2,
            server_speed: f64::INFINITY,
        }
    }
}

/// Server-side FC block state (parameters + AdaGrad accumulators).
struct FcState {
    w: Tensor,
    b: Tensor,
    acc_w: Tensor,
    acc_b: Tensor,
}

/// One FC training step through the `<net>_fc_step` artifact; updates
/// the state in place and returns (dfeat, loss).  The measured exclusive
/// execution time is padded to the modelled server speed.
fn fc_step(
    rt: &SharedRuntime,
    artifact: &str,
    st: &mut FcState,
    feat: &Tensor,
    y: &Tensor,
    speed: f64,
) -> Result<(Tensor, f32)> {
    let timer = PaddedTimer::start();
    // The four state tensors are unconditionally replaced from the
    // outputs, so move them instead of deep-copying (the FC block is the
    // big half of the model); on error the whole round aborts anyway.
    let empty = || Tensor::zeros(&[0]);
    let inputs = vec![
        std::mem::replace(&mut st.w, empty()),
        std::mem::replace(&mut st.b, empty()),
        std::mem::replace(&mut st.acc_w, empty()),
        std::mem::replace(&mut st.acc_b, empty()),
        feat.clone(),
        y.clone(),
    ];
    let (mut outs, ms) = rt.exec_exclusive(artifact, &inputs)?;
    ensure!(outs.len() == 6, "{artifact}: expected 6 outputs, got {}", outs.len());
    let loss = outs.pop().unwrap().item()?;
    let dfeat = outs.pop().unwrap();
    st.acc_b = outs.pop().unwrap();
    st.acc_w = outs.pop().unwrap();
    st.b = outs.pop().unwrap();
    st.w = outs.pop().unwrap();
    timer.pad_to(ms, speed);
    Ok((dfeat, loss))
}

/// Run the hybrid algorithm on a live cluster (the module docs describe
/// one round end to end).
///
/// Work units ride the ticket store, so the §2.1.2 invariants apply
/// unchanged: a shard lost to a killed client is redistributed by VCT
/// timeout, and a straggler's late answer is dropped as a counted
/// duplicate — the trainer consumes each shard's features exactly once
/// via the first-result-wins completion stream.
pub fn train(cluster: &Cluster, cfg: &HybridConfig) -> Result<TrainResult> {
    let spec = &cluster.spec;
    let net = cluster.cfg.net.clone();
    let shards = cluster.n_shards();
    let conv_names: Vec<String> = spec.conv_param_names().to_vec();
    let conv_shapes: Vec<Vec<usize>> =
        conv_names.iter().map(|n| spec.param_shapes[n].clone()).collect();
    let fc_artifact = format!("{net}_fc_step");

    // Pre-compile the server-side artifact so round 0 is not a
    // compilation sample (clients warm their own on first ticket).
    cluster.rt.load(&fc_artifact)?;

    let mut rng = SplitMix64::new(cfg.seed);
    let mut full = ParamSet::init(spec, &mut rng);
    let mut conv_params = full.conv_subset(spec);
    let mut conv_accums = ParamSet::zeros(spec).conv_subset(spec);
    let mut fc = FcState {
        w: full.get("fc_w")?.clone(),
        b: full.get("fc_b")?.clone(),
        acc_w: Tensor::zeros(full.get("fc_w")?.shape()),
        acc_b: Tensor::zeros(full.get("fc_b")?.shape()),
    };

    let bytes0 = cluster.bytes();
    let t0 = Instant::now();
    let mut curve = Curve::default();
    let (mut conv_batches, mut fc_steps, mut replay_steps) = (0u64, 0u64, 0u64);
    let mut mean_loss_last_round = f64::NAN;
    // Latest boundary features per shard, for replay.
    let mut feat_cache: Vec<Option<Tensor>> = vec![None; shards];
    let mut replay_cursor = 0usize;

    for round in 0..cfg.rounds {
        let pkey = params_key(&net, round);
        cluster.datasets().register(&pkey, pack_params(&conv_params.ordered()));
        let fwd_task = cluster.new_task(
            "conv_fwd",
            (0..shards)
                .map(|s| {
                    ConvFwdTask::ticket(&pkey, &shard_x_key(&net, s), &shard_y_key(&net, s), s)
                })
                .collect(),
        );
        let grad_task = cluster.alloc_task();

        let mut fwd_seen = 0usize;
        let mut grads: Vec<(f32, ParamSet)> = Vec::with_capacity(shards);
        let mut round_losses: Vec<f64> = Vec::new();
        let mut replay_left = cfg.max_replay_per_round;

        while grads.len() < shards {
            // Features first: each one unlocks an FC step and a backward
            // ticket, which is the round's critical path.
            if fwd_seen < shards {
                if let Some((_, v)) = cluster.store().next_completion(fwd_task, cfg.poll_ms) {
                    let shard = v.get("shard")?.as_usize()?;
                    ensure!(shard < shards, "conv_fwd returned unknown shard {shard}");
                    let feat = tensor_from_json(v.get("feat")?)?;
                    let y = cluster.shard_y(shard)?;
                    let (dfeat, loss) =
                        fc_step(&cluster.rt, &fc_artifact, &mut fc, &feat, &y, cfg.server_speed)?;
                    fc_steps += 1;
                    round_losses.push(loss as f64);
                    cluster.submit(
                        grad_task,
                        "conv_grad",
                        vec![ConvGradTask::ticket(&pkey, &shard_x_key(&net, shard), &dfeat, shard)],
                    );
                    feat_cache[shard] = Some(feat);
                    fwd_seen += 1;
                    continue;
                }
            }
            if let Some((_, v)) = cluster.store().next_completion(grad_task, cfg.poll_ms) {
                let blob = tensor_from_json(v.get("grads")?)?;
                let tensors = unflatten(&blob, &conv_shapes)?;
                let g = ParamSet::from_pairs(conv_names.iter().cloned().zip(tensors).collect());
                grads.push((spec.batch as f32, g));
                conv_batches += 1;
                continue;
            }
            // Nothing arrived within the poll window: replay an FC step
            // on a cached feature batch, if the round's budget allows.
            if replay_left > 0 {
                let cached: Vec<usize> =
                    (0..shards).filter(|&s| feat_cache[s].is_some()).collect();
                if !cached.is_empty() {
                    let shard = cached[replay_cursor % cached.len()];
                    replay_cursor += 1;
                    let feat = feat_cache[shard].as_ref().unwrap();
                    let y = cluster.shard_y(shard)?;
                    let (_dfeat, loss) =
                        fc_step(&cluster.rt, &fc_artifact, &mut fc, feat, &y, cfg.server_speed)?;
                    fc_steps += 1;
                    replay_steps += 1;
                    replay_left -= 1;
                    round_losses.push(loss as f64);
                }
            }
        }

        let agg = aggregate_gradients(&grads)?;
        adagrad::update_set(&mut conv_params, &mut conv_accums, &agg, spec.lr, spec.beta)?;

        // Evict the previous round's conv blob (one-round lag: its
        // tickets finished a full round ago, so even a redistributed
        // straggler has fetched it — memory stays bounded without racing
        // slow clients).
        if round > 0 {
            cluster.datasets().remove(&params_key(&net, round - 1));
        }

        let mean = round_losses.iter().sum::<f64>() / round_losses.len().max(1) as f64;
        mean_loss_last_round = mean;
        curve.push(round, t0.elapsed().as_secs_f64() * 1e3, mean);
        crate::log_debug!(
            "dist::hybrid",
            "round {round}: mean loss {mean:.4}, {} replay steps left",
            replay_left
        );
    }

    // Fold the client-trained conv stack and the server-trained FC block
    // back into one parameter set (what a deployment would checkpoint).
    full.merge(&conv_params)?;
    full.set("fc_w", fc.w)?;
    full.set("fc_b", fc.b)?;

    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let bytes1 = cluster.bytes();
    Ok(TrainResult {
        conv_batches,
        fc_steps,
        replay_steps,
        loss_curve: curve,
        params: full,
        stats: DistStats {
            algorithm: "hybrid".into(),
            clients: cluster.cfg.clients,
            conv_batches_per_s: conv_batches as f64 / elapsed,
            fc_steps_per_s: fc_steps as f64 / elapsed,
            mean_loss_last_round,
            bytes: (bytes1.0 - bytes0.0, bytes1.1 - bytes0.1),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::dist::{ClusterConfig, CommModel};
    use crate::runtime;
    use crate::transport::LinkModel;

    /// §4 acceptance shape: 4 workers over modelled Internet links reach
    /// a lower loss than round 0 within 6 rounds from a fixed seed.
    /// Skips (with a message) when artifacts/XLA are unavailable.
    #[test]
    fn four_internet_workers_reduce_loss_in_six_rounds() {
        let Some(rt) = runtime::open_shared_or_skip() else { return };
        let dataset = data::mnist_train(600, 77);
        let mut cfg = ClusterConfig::quick_test("mnist", 4);
        cfg.link = LinkModel::INTERNET; // bytes priced at Internet grade
        let cluster = Cluster::start(cfg, rt, &dataset).unwrap();
        let hycfg = HybridConfig { rounds: 6, seed: 1234, ..Default::default() };
        let result = train(&cluster, &hycfg).unwrap();
        cluster.shutdown();
        assert_eq!(result.conv_batches, 6 * 4);
        let first = result.loss_curve.head_mean(1);
        let last = result.loss_curve.tail_mean(1);
        assert!(last < first, "loss did not fall: round0 {first} -> round5 {last}");
        // The byte advantage of the hybrid exchange at the paper's scale:
        // fewer floats per round than synchronous full exchange.
        let m = CommModel { conv_params: 3_700_000, fc_params: 58_600_000, boundary: 50 * 9216 };
        assert!(m.hybrid_floats(4, 4) < m.he_sync_floats(4, 4));
    }
}
