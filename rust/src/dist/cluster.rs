//! A simulated training cluster: one in-process server (ticket store +
//! distributor) and N worker threads replaying the §2.1.2 browser loop
//! over [`transport::local`] links.
//!
//! The cluster owns everything the three trainers share — the dataset
//! shards (registered as wire datasets so clients download and cache
//! them exactly like the paper's browsers), the task registry with the
//! §4 work units, and the worker fleet — so a trainer is just a server
//! loop that publishes round datasets, submits tickets, and consumes
//! completions from the store.
//!
//! [`transport::local`]: crate::transport::local

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{ensure, Context, Result};

use crate::coordinator::distributor::DEFAULT_MAX_BATCH;
use crate::coordinator::{Distributor, DistributorConfig};
use crate::data::Dataset;
use crate::runtime::{NetSpec, SharedRuntime, Tensor};
use crate::store::{Scheduler, StoreConfig, TaskId, TicketStore};
use crate::tasks::train::{shard_x_key, shard_y_key, ConvFwdTask, ConvGradTask, GradTask};
use crate::tasks::{DatasetStore, Registry};
use crate::transport::local::{self, LocalConnector};
use crate::transport::{Conn, LinkModel};
use crate::util::clock;
use crate::util::json::Value;
use crate::worker::{DeviceProfile, Worker, WorkerReport};

/// How to build a cluster.  All fields are public so benches can tweak
/// one knob (Fig 5 sets `profile` and `n_shards`) without a builder.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Net name in the artifact manifest ("mnist" | "cifar").
    pub net: String,
    /// Number of worker (browser) nodes.
    pub clients: usize,
    /// Number of fixed mini-batch shards carved out of the dataset; each
    /// shard is exactly one artifact batch (`spec.batch` samples).
    pub n_shards: usize,
    /// Device profile applied to every worker (server speed is a trainer
    /// knob, [`crate::dist::hybrid::HybridConfig::server_speed`]).
    pub profile: DeviceProfile,
    /// Link model between workers and the server.
    pub link: LinkModel,
    /// Actually sleep for the modelled link cost (benches measuring wall
    /// time) or only account bytes (tests).
    pub sleep_on_link: bool,
    /// Ticket-store redistribution policy for the run.
    pub store: StoreConfig,
    /// Worker prefetch ceiling ([`Worker::prefetch_cap`]): how many
    /// tickets one poll may fetch.  Compute-bound training tickets stay
    /// effectively unbatched (the batch only grows when a whole batch
    /// beats one round trip); `1` pins the legacy single-ticket wire.
    pub prefetch_cap: usize,
    /// Retry hint handed to idle workers
    /// ([`DistributorConfig::idle_retry_ms`]).
    pub idle_retry_ms: u64,
    /// Server-side cap on one dispatched batch
    /// ([`DistributorConfig::max_batch`]).
    pub max_batch: usize,
    /// The active failure path
    /// ([`DistributorConfig::release_on_disconnect`]): release a
    /// vanished connection's in-flight tickets immediately.  `false`
    /// reproduces the paper's passive baseline, where stranded tickets
    /// wait out the §2.1.2 redistribution windows.
    pub disconnect_release: bool,
}

impl ClusterConfig {
    /// Deterministic test shape: one shard per client, byte-accounted but
    /// latency-free FAST_LAN links, and redistribution timeouts far
    /// beyond the test horizon so every ticket is served exactly once
    /// (making ticket/byte counts exact).
    pub fn quick_test(net: &str, clients: usize) -> ClusterConfig {
        ClusterConfig {
            net: net.to_string(),
            clients,
            n_shards: clients.max(1),
            profile: DeviceProfile::native(),
            link: LinkModel::FAST_LAN,
            sleep_on_link: false,
            store: StoreConfig {
                requeue_after_ms: 600_000,
                min_redistribute_ms: 600_000,
                requeue_on_error: true,
                ..StoreConfig::default()
            },
            prefetch_cap: 4,
            idle_retry_ms: 20,
            max_batch: DEFAULT_MAX_BATCH,
            disconnect_release: true,
        }
    }
}

/// A running cluster: server-side state plus the worker fleet.  Create
/// with [`Cluster::start`], drive it with one of the trainers, then
/// [`Cluster::shutdown`] to collect the per-worker reports.
pub struct Cluster {
    /// The artifact runtime every trainer executes through.
    pub rt: SharedRuntime,
    /// The net being trained (resolved from `cfg.net` at start).
    pub spec: NetSpec,
    /// The configuration the cluster was started with.
    pub cfg: ClusterConfig,
    store: Arc<dyn Scheduler>,
    datasets: Arc<DatasetStore>,
    distributor: Arc<Distributor>,
    /// Kept alive so the acceptor only exits at shutdown.
    connector: LocalConnector,
    workers: Vec<JoinHandle<WorkerReport>>,
    stop: Arc<AtomicBool>,
    acceptor: JoinHandle<()>,
    next_task: AtomicU64,
}

impl Cluster {
    /// Spin up the server and `cfg.clients` worker threads, register the
    /// §4 task definitions and the dataset shards, and start serving.
    pub fn start(cfg: ClusterConfig, rt: SharedRuntime, dataset: &Dataset) -> Result<Cluster> {
        let spec = rt.net(&cfg.net)?.clone();
        ensure!(cfg.clients > 0, "cluster needs at least one client");
        ensure!(cfg.n_shards > 0, "cluster needs at least one shard");
        ensure!(
            dataset.hw == spec.input_hw && dataset.channels == spec.input_c,
            "dataset {}x{}x{} does not match net {} ({}x{}x{})",
            dataset.hw,
            dataset.hw,
            dataset.channels,
            spec.name,
            spec.input_hw,
            spec.input_hw,
            spec.input_c
        );
        ensure!(
            cfg.n_shards * spec.batch <= dataset.len(),
            "{} shards of batch {} need {} samples, dataset has {}",
            cfg.n_shards,
            spec.batch,
            cfg.n_shards * spec.batch,
            dataset.len()
        );

        let conv_shapes: Vec<Vec<usize>> =
            spec.conv_param_names().iter().map(|n| spec.param_shapes[n].clone()).collect();
        let param_shapes: Vec<Vec<usize>> =
            spec.param_names.iter().map(|n| spec.param_shapes[n].clone()).collect();

        let mut registry = Registry::new();
        registry.register(Arc::new(ConvFwdTask {
            net: cfg.net.clone(),
            conv_shapes: conv_shapes.clone(),
        }));
        registry.register(Arc::new(ConvGradTask { net: cfg.net.clone(), conv_shapes }));
        registry.register(Arc::new(GradTask { net: cfg.net.clone(), param_shapes }));

        // Fixed shards: shard s holds samples [s*batch, (s+1)*batch).
        // Stable keys mean workers download each shard once and serve it
        // from their LRU across all rounds (the paper's browser cache).
        let datasets = Arc::new(DatasetStore::new());
        for shard in 0..cfg.n_shards {
            let idx: Vec<usize> = (shard * spec.batch..(shard + 1) * spec.batch).collect();
            datasets.register(&shard_x_key(&cfg.net, shard), dataset.batch_images(&idx));
            datasets.register(&shard_y_key(&cfg.net, shard), dataset.batch_onehot(&idx));
        }

        let store: Arc<dyn Scheduler> = Arc::new(TicketStore::new(cfg.store.clone()));
        let distributor = Distributor::from_parts_with(
            Arc::clone(&store),
            registry.clone(),
            Arc::clone(&datasets),
            DistributorConfig {
                idle_retry_ms: cfg.idle_retry_ms,
                max_batch: cfg.max_batch,
                release_on_disconnect: cfg.disconnect_release,
            },
        );
        let (listener, connector) = local::endpoint(cfg.link, cfg.sleep_on_link);
        let acceptor = distributor.serve(Box::new(listener));

        let stop = Arc::new(AtomicBool::new(false));
        let workers = (0..cfg.clients)
            .map(|i| {
                let connector = connector.clone();
                let registry = registry.clone();
                let stop = Arc::clone(&stop);
                let rt = Arc::clone(&rt);
                let profile = cfg.profile.clone();
                let prefetch_cap = cfg.prefetch_cap;
                std::thread::spawn(move || {
                    let mut w = Worker::new(&format!("client{i}"), profile, registry)
                        .with_runtime(rt)
                        .with_prefetch_cap(prefetch_cap);
                    w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
                })
            })
            .collect();

        Ok(Cluster {
            rt,
            spec,
            cfg,
            store,
            datasets,
            distributor,
            connector,
            workers,
            stop,
            acceptor,
            next_task: AtomicU64::new(1),
        })
    }

    /// The shared ticket store (trainers submit and collect through it,
    /// so §2.1.2 redistribution covers training work units too).
    pub fn store(&self) -> &Arc<dyn Scheduler> {
        &self.store
    }

    /// The wire dataset registry (shards + per-round parameter blobs).
    pub fn datasets(&self) -> &Arc<DatasetStore> {
        &self.datasets
    }

    /// Number of fixed mini-batch shards (`cfg.n_shards`).
    pub fn n_shards(&self) -> usize {
        self.cfg.n_shards
    }

    /// Allocate a fresh task id (trainers stream tickets into it later).
    pub fn alloc_task(&self) -> TaskId {
        TaskId(self.next_task.fetch_add(1, Ordering::SeqCst))
    }

    /// Enqueue tickets under an already-allocated task id.
    pub fn submit(&self, task: TaskId, task_name: &str, payloads: Vec<Value>) {
        self.store.create_tickets(task, task_name, payloads, clock::now_ms());
    }

    /// Allocate-and-enqueue in one step.
    pub fn new_task(&self, task_name: &str, payloads: Vec<Value>) -> TaskId {
        let id = self.alloc_task();
        self.submit(id, task_name, payloads);
        id
    }

    /// The server-side copy of a shard's one-hot labels (the hybrid FC
    /// step consumes these without touching the wire).
    pub fn shard_y(&self, shard: usize) -> Result<Arc<Tensor>> {
        self.datasets
            .get(&shard_y_key(&self.cfg.net, shard))
            .with_context(|| format!("shard {shard} labels not registered"))
    }

    /// Server-side wire counters so trainers can report traffic deltas:
    /// (bytes sent to clients, bytes received from clients).
    pub fn bytes(&self) -> (u64, u64) {
        (
            self.distributor.stats.bytes_sent.load(Ordering::Relaxed),
            self.distributor.stats.bytes_received.load(Ordering::Relaxed),
        )
    }

    /// Stop the fleet and the distributor; returns one report per worker
    /// (in spawn order).
    pub fn shutdown(self) -> Vec<WorkerReport> {
        self.stop.store(true, Ordering::SeqCst);
        let reports: Vec<WorkerReport> =
            self.workers.into_iter().map(|h| h.join().unwrap_or_default()).collect();
        self.distributor.stop();
        // Dropping the last connector makes the listener's accept fail,
        // which ends the acceptor loop.
        drop(self.connector);
        let _ = self.acceptor.join();
        reports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_test_shape() {
        let cfg = ClusterConfig::quick_test("mnist", 3);
        assert_eq!(cfg.net, "mnist");
        assert_eq!(cfg.clients, 3);
        assert_eq!(cfg.n_shards, 3);
        assert!(cfg.profile.speed.is_infinite());
        assert!(!cfg.sleep_on_link);
        // Redistribution must not fire within any test horizon, so
        // ticket and byte counts are exact.
        assert!(cfg.store.requeue_after_ms >= 600_000);
        assert!(cfg.store.min_redistribute_ms >= 600_000);
        // Batched polling on, at a modest ceiling: every fetched ticket
        // is executed and flushed, so counts stay exact.
        assert_eq!(cfg.prefetch_cap, 4);
        // Distributor knobs plumbed, not hardcoded; the active failure
        // path is on by default (quick tests shut down orderly, so it
        // never fires unless a worker actually strands work).
        assert_eq!(cfg.idle_retry_ms, 20);
        assert_eq!(cfg.max_batch, DEFAULT_MAX_BATCH);
        assert!(cfg.disconnect_release);
    }

    #[test]
    fn quick_test_never_zero_shards() {
        assert_eq!(ClusterConfig::quick_test("cifar", 0).n_shards, 1);
    }
}
