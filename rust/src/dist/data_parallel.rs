//! Shared driver for the two data-parallel baselines.
//!
//! [`mlitb`](crate::dist::mlitb) and [`he_sync`](crate::dist::he_sync)
//! run the *same* workload — full parameters out as a round dataset,
//! one `grad_all` ticket per shard, full gradients back — and differ
//! only in *when* the update is applied ([`Apply`]).  Keeping one driver
//! guarantees the byte volumes stay identical (the property
//! `CommModel::he_sync_floats == mlitb_floats` encodes) and that fixes
//! land in both.

use std::time::Instant;

use anyhow::Result;

use crate::dist::mlitb::all_params_key;
use crate::dist::{aggregate_gradients, Cluster, DistStats, TrainResult};
use crate::nn::adagrad;
use crate::nn::metrics::Curve;
use crate::nn::params::ParamSet;
use crate::store::Scheduler as _;
use crate::tasks::tensor_from_json;
use crate::tasks::train::{pack_params, shard_x_key, shard_y_key, unflatten, GradTask};
use crate::util::rng::SplitMix64;

/// When gradients hit the parameters.
pub(crate) enum Apply {
    /// MLitB: each shard's gradient updates the model as it arrives.
    PerArrival,
    /// he-sync: barrier, then one update from the sample-weighted mean.
    Barrier,
}

pub(crate) fn train(
    cluster: &Cluster,
    rounds: u64,
    seed: u64,
    apply: Apply,
    algorithm: &str,
) -> Result<TrainResult> {
    let spec = &cluster.spec;
    let net = cluster.cfg.net.clone();
    let shards = cluster.n_shards();
    let shapes: Vec<Vec<usize>> =
        spec.param_names.iter().map(|n| spec.param_shapes[n].clone()).collect();

    let mut rng = SplitMix64::new(seed);
    let mut params = ParamSet::init(spec, &mut rng);
    let mut accums = ParamSet::zeros(spec);

    let bytes0 = cluster.bytes();
    let t0 = Instant::now();
    let mut curve = Curve::default();
    let (mut conv_batches, mut fc_steps) = (0u64, 0u64);
    let mut mean_loss_last_round = f64::NAN;

    for round in 0..rounds {
        let pkey = all_params_key(&net, round);
        cluster.datasets().register(&pkey, pack_params(&params.ordered()));
        let task = cluster.new_task(
            "grad_all",
            (0..shards)
                .map(|s| GradTask::ticket(&pkey, &shard_x_key(&net, s), &shard_y_key(&net, s), s))
                .collect(),
        );

        let mut seen = 0usize;
        let mut parts: Vec<(f32, ParamSet)> = Vec::with_capacity(shards);
        let mut round_losses: Vec<f64> = Vec::new();
        while seen < shards {
            let Some((_, v)) = cluster.store().next_completion(task, 20) else {
                continue;
            };
            let blob = tensor_from_json(v.get("grads")?)?;
            let tensors = unflatten(&blob, &shapes)?;
            let g = ParamSet::from_pairs(spec.param_names.iter().cloned().zip(tensors).collect());
            match apply {
                Apply::PerArrival => {
                    adagrad::update_set(&mut params, &mut accums, &g, spec.lr, spec.beta)?;
                    fc_steps += 1;
                }
                Apply::Barrier => parts.push((spec.batch as f32, g)),
            }
            round_losses.push(v.get("loss")?.as_f64()?);
            conv_batches += 1;
            seen += 1;
        }
        if let Apply::Barrier = apply {
            let agg = aggregate_gradients(&parts)?;
            adagrad::update_set(&mut params, &mut accums, &agg, spec.lr, spec.beta)?;
            fc_steps += 1;
        }

        // Evict the previous round's parameter blob: its tickets are all
        // done one full round ago, so even a redistributed straggler has
        // fetched it by now (one-round lag keeps memory bounded without
        // racing slow clients).
        if round > 0 {
            cluster.datasets().remove(&all_params_key(&net, round - 1));
        }

        let mean = round_losses.iter().sum::<f64>() / round_losses.len().max(1) as f64;
        mean_loss_last_round = mean;
        curve.push(round, t0.elapsed().as_secs_f64() * 1e3, mean);
    }

    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let bytes1 = cluster.bytes();
    Ok(TrainResult {
        conv_batches,
        fc_steps,
        replay_steps: 0,
        loss_curve: curve,
        params,
        stats: DistStats {
            algorithm: algorithm.to_string(),
            clients: cluster.cfg.clients,
            conv_batches_per_s: conv_batches as f64 / elapsed,
            fc_steps_per_s: fc_steps as f64 / elapsed,
            mean_loss_last_round,
            bytes: (bytes1.0 - bytes0.0, bytes1.1 - bytes0.1),
        },
    })
}
