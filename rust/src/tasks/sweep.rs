//! SweepTask: a hyperparameter-sweep fan-out work unit.
//!
//! The fourth built-in task type (after prime/kNN/train), added so the
//! churn soak (`crate::sim`) exercises scenario diversity: many tiny
//! independent evaluations whose *aggregation* (argmin over validation
//! loss) happens back on the coordinator — the classic embarrassingly
//! parallel sweep every volunteer-computing fleet runs.
//!
//! Each ticket evaluates one `(learning rate, regularization)` grid
//! point.  The "validation loss" is a deterministic closed-form
//! surrogate — a convex bowl over `(log10(lr), reg)` with a small
//! index-derived ripple standing in for evaluation noise — so results
//! are exactly reproducible across runs and devices (the soak's
//! bit-identical-trace guarantee extends through task execution) and
//! the winning grid point is known in closed form for tests.

use anyhow::Result;

use super::{TaskContext, TaskDef, TaskOutput};
use crate::util::json::Value;

pub struct SweepTask;

/// The sweep's optimal point: the loss surface is minimized at
/// `lr = 3e-3, reg = 1e-2` (up to the ripple term).
pub const OPT_LR: f64 = 3e-3;
pub const OPT_REG: f64 = 1e-2;

/// The deterministic loss surrogate: a convex bowl over
/// `(log10(lr), reg)` plus a tiny index-keyed ripple (so equal grid
/// points at different indexes still produce distinct, reproducible
/// values — evaluation "noise" without an RNG).
pub fn surrogate_loss(lr: f64, reg: f64, index: u64) -> f64 {
    let dl = (lr.max(1e-12)).log10() - OPT_LR.log10();
    let dr = reg - OPT_REG;
    let ripple = ((index.wrapping_mul(0x9E37_79B9)) % 1000) as f64 * 1e-6;
    dl * dl + 5.0 * dr * dr + ripple
}

impl TaskDef for SweepTask {
    fn name(&self) -> &str {
        "sweep"
    }

    fn code_bytes(&self) -> usize {
        // sweep_task.js + the evaluation harness, roughly.
        2048
    }

    fn execute(&self, input: &Value, _ctx: &mut dyn TaskContext) -> Result<TaskOutput> {
        let lr = input.get("lr")?.as_f64()?;
        let reg = input.get("reg")?.as_f64()?;
        let index = input.get("index")?.as_u64()?;
        anyhow::ensure!(lr > 0.0, "lr must be positive, got {lr}");
        anyhow::ensure!(reg >= 0.0, "reg must be non-negative, got {reg}");
        let loss = surrogate_loss(lr, reg, index);
        Ok(TaskOutput {
            value: Value::obj(vec![
                ("index", Value::num(index as f64)),
                ("lr", Value::num(lr)),
                ("reg", Value::num(reg)),
                ("loss", Value::num(loss)),
            ]),
            // A modelled evaluation cost: one short validation pass.
            modelled_ms: Some(8.0),
        })
    }
}

/// Fan-out: the full `lrs x regs` grid as ticket payloads, indexed in
/// row-major order (lr-major) — `calculate(grid(..))` is the sweep's
/// whole dispatch side.
pub fn grid(lrs: &[f64], regs: &[f64]) -> Vec<Value> {
    let mut inputs = Vec::with_capacity(lrs.len() * regs.len());
    let mut index = 0u64;
    for &lr in lrs {
        for &reg in regs {
            inputs.push(Value::obj(vec![
                ("lr", Value::num(lr)),
                ("reg", Value::num(reg)),
                ("index", Value::num(index as f64)),
            ]));
            index += 1;
        }
    }
    inputs
}

/// Aggregation: the winning `(lr, reg, loss)` — lowest loss, ties
/// broken by lowest index so the answer is deterministic even with
/// duplicated grid points.
pub fn best(results: &[Value]) -> Result<(f64, f64, f64)> {
    anyhow::ensure!(!results.is_empty(), "sweep produced no results");
    let mut best: Option<(u64, f64, f64, f64)> = None; // (index, lr, reg, loss)
    for r in results {
        let index = r.get("index")?.as_u64()?;
        let lr = r.get("lr")?.as_f64()?;
        let reg = r.get("reg")?.as_f64()?;
        let loss = r.get("loss")?.as_f64()?;
        let better = match &best {
            None => true,
            Some((bi, _, _, bl)) => loss < *bl || (loss == *bl && index < *bi),
        };
        if better {
            best = Some((index, lr, reg, loss));
        }
    }
    let (_, lr, reg, loss) = best.unwrap();
    Ok((lr, reg, loss))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::test_support::FakeContext;

    #[test]
    fn grid_enumerates_row_major_with_sequential_indexes() {
        let inputs = grid(&[1e-3, 3e-3], &[0.0, 1e-2, 1e-1]);
        assert_eq!(inputs.len(), 6);
        for (i, v) in inputs.iter().enumerate() {
            assert_eq!(v.get("index").unwrap().as_u64().unwrap(), i as u64);
        }
        assert_eq!(inputs[0].get("lr").unwrap().as_f64().unwrap(), 1e-3);
        assert_eq!(inputs[0].get("reg").unwrap().as_f64().unwrap(), 0.0);
        // lr-major: the second lr starts after all regs of the first.
        assert_eq!(inputs[3].get("lr").unwrap().as_f64().unwrap(), 3e-3);
        assert_eq!(inputs[3].get("reg").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn execute_is_deterministic_and_aggregation_finds_the_optimum() {
        let t = SweepTask;
        let mut ctx = FakeContext::default();
        let inputs = grid(&[1e-4, 1e-3, 3e-3, 1e-2], &[0.0, 1e-2, 1e-1]);
        let run = |ctx: &mut FakeContext| -> Vec<Value> {
            inputs.iter().map(|i| t.execute(i, ctx).unwrap().value).collect()
        };
        let a = run(&mut ctx);
        let b = run(&mut ctx);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.get("loss").unwrap().as_f64().unwrap(),
                y.get("loss").unwrap().as_f64().unwrap(),
                "same ticket, same loss"
            );
        }
        let (lr, reg, loss) = best(&a).unwrap();
        assert_eq!((lr, reg), (OPT_LR, OPT_REG), "argmin lands on the bowl's bottom");
        assert!(loss < 1e-3, "optimal loss is ripple-sized, got {loss}");
    }

    #[test]
    fn best_breaks_ties_by_lowest_index() {
        let mk = |index: f64, loss: f64| {
            Value::obj(vec![
                ("index", Value::num(index)),
                ("lr", Value::num(index + 1.0)), // distinguishable stand-ins
                ("reg", Value::num(0.0)),
                ("loss", Value::num(loss)),
            ])
        };
        // Same loss at indexes 2 and 0 (out of order): index 0 wins.
        let (lr, _, _) = best(&[mk(2.0, 0.5), mk(0.0, 0.5), mk(1.0, 0.7)]).unwrap();
        assert_eq!(lr, 1.0);
    }

    #[test]
    fn malformed_inputs_error() {
        let t = SweepTask;
        let mut ctx = FakeContext::default();
        assert!(t.execute(&Value::Null, &mut ctx).is_err());
        let neg = Value::obj(vec![
            ("lr", Value::num(-1.0)),
            ("reg", Value::num(0.0)),
            ("index", Value::num(0.0)),
        ]);
        assert!(t.execute(&neg, &mut ctx).is_err());
        assert!(best(&[]).is_err());
    }
}
