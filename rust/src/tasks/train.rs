//! Distributed-training work units (the paper's §4 algorithm + the
//! MLitB baseline).
//!
//! Hybrid (paper) — two client-side tasks:
//! * [`ConvFwdTask`]: run the conv stack forward on a batch shard with
//!   the round's conv parameters; return the boundary features.
//! * [`ConvGradTask`]: given the server's boundary cotangent `dfeat`,
//!   recompute the conv forward and return conv-parameter gradients
//!   (recompute-vs-ship ablation: DESIGN.md §6.1).
//!
//! MLitB baseline — one task:
//! * [`GradTask`]: full-network gradients on a batch shard; the server
//!   averages and updates (Meeds et al.'s scheme, §4.1).
//!
//! Conv parameters travel as *round datasets* (`<net>_convp_r<round>`):
//! every client of a round fetches the same blob once and caches it,
//! exactly like the paper's browsers cache external data files.  Batch
//! shards are datasets too (`<net>_x_<shard>` / `<net>_y_<shard>`),
//! cached across rounds when the trainer reuses shards.


use anyhow::Result;

use super::{tensor_to_json, TaskContext, TaskDef, TaskOutput};
use crate::runtime::Tensor;
use crate::util::json::Value;

/// Unpack a flat parameter blob `[total]` into tensors of `shapes`.
pub fn unflatten(blob: &Tensor, shapes: &[Vec<usize>]) -> Result<Vec<Tensor>> {
    let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
    anyhow::ensure!(blob.len() == total, "param blob {} != expected {}", blob.len(), total);
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for s in shapes {
        let n: usize = s.iter().product();
        out.push(Tensor::new(s.clone(), blob.data()[off..off + n].to_vec())?);
        off += n;
    }
    Ok(out)
}

/// Pack tensors into one flat blob (inverse of [`unflatten`]).
pub fn flatten(tensors: &[Tensor]) -> Tensor {
    let mut data = Vec::with_capacity(tensors.iter().map(|t| t.len()).sum());
    for t in tensors {
        data.extend_from_slice(t.data());
    }
    let n = data.len();
    Tensor::new(vec![n], data).unwrap()
}

fn common_keys(input: &Value) -> Result<(String, String, String)> {
    Ok((
        input.get("params_key")?.as_str()?.to_string(),
        input.get("x_key")?.as_str()?.to_string(),
        input.get("y_key")?.as_str()?.to_string(),
    ))
}

/// Client-side conv forward (hybrid round, phase 1).
pub struct ConvFwdTask {
    pub net: String,
    pub conv_shapes: Vec<Vec<usize>>,
}

impl ConvFwdTask {
    pub fn ticket(params_key: &str, x_key: &str, y_key: &str, shard: usize) -> Value {
        Value::obj(vec![
            ("params_key", Value::str(params_key)),
            ("x_key", Value::str(x_key)),
            ("y_key", Value::str(y_key)),
            ("shard", Value::num(shard as f64)),
        ])
    }
}

impl TaskDef for ConvFwdTask {
    fn name(&self) -> &str {
        "conv_fwd"
    }

    fn dataset_refs(&self, input: &Value) -> Vec<String> {
        ["params_key", "x_key"]
            .iter()
            .filter_map(|k| input.opt(k).and_then(|v| v.as_str().ok()).map(String::from))
            .collect()
    }

    fn execute(&self, input: &Value, ctx: &mut dyn TaskContext) -> Result<TaskOutput> {
        let (pk, xk, _) = common_keys(input)?;
        let blob = ctx.dataset(&pk)?;
        let x = ctx.dataset(&xk)?;
        let mut args = unflatten(&blob, &self.conv_shapes)?;
        args.push((*x).clone());
        let rt = ctx.runtime()?;
        let (outs, ms) = rt.exec_exclusive(&format!("{}_conv_fwd", self.net), &args)?;
        Ok(TaskOutput {
            value: Value::obj(vec![
                ("shard", input.get("shard")?.clone()),
                ("feat", tensor_to_json(&outs[0])),
            ]),
            modelled_ms: Some(ms),
        })
    }
}

/// Client-side conv backward (hybrid round, phase 2).
pub struct ConvGradTask {
    pub net: String,
    pub conv_shapes: Vec<Vec<usize>>,
}

impl ConvGradTask {
    pub fn ticket(params_key: &str, x_key: &str, dfeat: &Tensor, shard: usize) -> Value {
        Value::obj(vec![
            ("params_key", Value::str(params_key)),
            ("x_key", Value::str(x_key)),
            ("dfeat", tensor_to_json(dfeat)),
            ("shard", Value::num(shard as f64)),
        ])
    }
}

impl TaskDef for ConvGradTask {
    fn name(&self) -> &str {
        "conv_grad"
    }

    fn dataset_refs(&self, input: &Value) -> Vec<String> {
        ["params_key", "x_key"]
            .iter()
            .filter_map(|k| input.opt(k).and_then(|v| v.as_str().ok()).map(String::from))
            .collect()
    }

    fn execute(&self, input: &Value, ctx: &mut dyn TaskContext) -> Result<TaskOutput> {
        let pk = input.get("params_key")?.as_str()?.to_string();
        let xk = input.get("x_key")?.as_str()?.to_string();
        let dfeat = super::tensor_from_json(input.get("dfeat")?)?;
        let blob = ctx.dataset(&pk)?;
        let x = ctx.dataset(&xk)?;
        let mut args = unflatten(&blob, &self.conv_shapes)?;
        args.push((*x).clone());
        args.push(dfeat);
        let rt = ctx.runtime()?;
        let (outs, ms) = rt.exec_exclusive(&format!("{}_conv_grad", self.net), &args)?;
        Ok(TaskOutput {
            value: Value::obj(vec![
                ("shard", input.get("shard")?.clone()),
                ("grads", tensor_to_json(&flatten(&outs))),
            ]),
            modelled_ms: Some(ms),
        })
    }
}

/// MLitB baseline: full-network gradient on a batch shard.
pub struct GradTask {
    pub net: String,
    pub param_shapes: Vec<Vec<usize>>,
}

impl GradTask {
    pub fn ticket(params_key: &str, x_key: &str, y_key: &str, shard: usize) -> Value {
        ConvFwdTask::ticket(params_key, x_key, y_key, shard)
    }
}

impl TaskDef for GradTask {
    fn name(&self) -> &str {
        "grad_all"
    }

    fn dataset_refs(&self, input: &Value) -> Vec<String> {
        ["params_key", "x_key", "y_key"]
            .iter()
            .filter_map(|k| input.opt(k).and_then(|v| v.as_str().ok()).map(String::from))
            .collect()
    }

    fn execute(&self, input: &Value, ctx: &mut dyn TaskContext) -> Result<TaskOutput> {
        let (pk, xk, yk) = common_keys(input)?;
        let blob = ctx.dataset(&pk)?;
        let x = ctx.dataset(&xk)?;
        let y = ctx.dataset(&yk)?;
        let mut args = unflatten(&blob, &self.param_shapes)?;
        args.push((*x).clone());
        args.push((*y).clone());
        let rt = ctx.runtime()?;
        let (mut outs, ms) = rt.exec_exclusive(&format!("{}_grad", self.net), &args)?;
        let loss = outs.pop().unwrap(); // last output is the scalar loss
        Ok(TaskOutput {
            value: Value::obj(vec![
                ("shard", input.get("shard")?.clone()),
                ("grads", tensor_to_json(&flatten(&outs))),
                ("loss", Value::num(loss.item()? as f64)),
            ]),
            modelled_ms: Some(ms),
        })
    }
}

/// Round-dataset key helpers shared with the dist drivers.
pub fn params_key(net: &str, round: u64) -> String {
    format!("{net}_convp_r{round}")
}

pub fn shard_x_key(net: &str, shard: usize) -> String {
    format!("{net}_x_{shard}")
}

pub fn shard_y_key(net: &str, shard: usize) -> String {
    format!("{net}_y_{shard}")
}

/// Pack a set of tensors for the dataset store (flat blob).
pub fn pack_params(tensors: &[Tensor]) -> Tensor {
    flatten(tensors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_unflatten_roundtrip() {
        let a = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let b = Tensor::new(vec![4], vec![9.0, 8.0, 7.0, 6.0]).unwrap();
        let blob = flatten(&[a.clone(), b.clone()]);
        assert_eq!(blob.shape(), &[10]);
        let back = unflatten(&blob, &[vec![2, 3], vec![4]]).unwrap();
        assert_eq!(back[0], a);
        assert_eq!(back[1], b);
        assert!(unflatten(&blob, &[vec![3, 3], vec![4]]).is_err());
    }

    #[test]
    fn ticket_payloads_carry_keys() {
        let p = ConvFwdTask::ticket("pk", "xk", "yk", 3);
        assert_eq!(p.get("params_key").unwrap().as_str().unwrap(), "pk");
        assert_eq!(p.get("shard").unwrap().as_usize().unwrap(), 3);
        let d = Tensor::new(vec![2], vec![1.0, -1.0]).unwrap();
        let g = ConvGradTask::ticket("pk", "xk", &d, 1);
        let back = crate::tasks::tensor_from_json(g.get("dfeat").unwrap()).unwrap();
        assert_eq!(back, d);
    }

    #[test]
    fn key_naming_is_stable() {
        assert_eq!(params_key("cifar", 12), "cifar_convp_r12");
        assert_eq!(shard_x_key("cifar", 0), "cifar_x_0");
        assert_eq!(shard_y_key("mnist", 3), "mnist_y_3");
    }
}
