//! Table 2's workload: nearest-neighbour MNIST classification, chunked.
//!
//! The paper classifies 1,000 test images against 60,000 training images
//! by splitting the work across browsers.  Here each ticket carries
//! (query window, training chunk window); the worker fetches both as
//! datasets (cached — training chunks are reused across query windows),
//! runs the `knn_chunk` artifact (whose distance matrix is the L1 Pallas
//! matmul), and returns per-query (min distance², argmin within chunk).
//! The project folds chunk results with `fold_min_argmin` and maps the
//! winning global index to its label.

use anyhow::Result;

use super::{tensor_to_json, TaskContext, TaskDef, TaskOutput};
use crate::util::json::Value;

pub struct KnnChunkTask {
    /// Artifact to run: `knn_chunk` (100x2000) or `knn_chunk_small`.
    pub artifact: String,
    pub query_rows: usize,
    pub chunk_rows: usize,
}

impl KnnChunkTask {
    pub fn standard() -> KnnChunkTask {
        KnnChunkTask { artifact: "knn_chunk".into(), query_rows: 100, chunk_rows: 2000 }
    }

    pub fn small() -> KnnChunkTask {
        KnnChunkTask { artifact: "knn_chunk_small".into(), query_rows: 20, chunk_rows: 200 }
    }

    /// Ticket payload for (query window q, train chunk c).
    pub fn ticket(&self, query_key: &str, chunk_key: &str, chunk_offset: usize) -> Value {
        Value::obj(vec![
            ("query_key", Value::str(query_key)),
            ("chunk_key", Value::str(chunk_key)),
            ("chunk_offset", Value::num(chunk_offset as f64)),
        ])
    }
}

impl TaskDef for KnnChunkTask {
    fn name(&self) -> &str {
        "knn_chunk"
    }

    fn code_bytes(&self) -> usize {
        2048
    }

    fn dataset_refs(&self, input: &Value) -> Vec<String> {
        let mut keys = Vec::new();
        for k in ["query_key", "chunk_key"] {
            if let Some(v) = input.opt(k) {
                if let Ok(s) = v.as_str() {
                    keys.push(s.to_string());
                }
            }
        }
        keys
    }

    fn execute(&self, input: &Value, ctx: &mut dyn TaskContext) -> Result<TaskOutput> {
        let q = ctx.dataset(input.get("query_key")?.as_str()?)?;
        let t = ctx.dataset(input.get("chunk_key")?.as_str()?)?;
        anyhow::ensure!(
            q.shape() == [self.query_rows, 784],
            "query shape {:?} != [{}, 784]",
            q.shape(),
            self.query_rows
        );
        anyhow::ensure!(
            t.shape() == [self.chunk_rows, 784],
            "chunk shape {:?} != [{}, 784]",
            t.shape(),
            self.chunk_rows
        );
        let rt = ctx.runtime()?;
        // Exclusive timing -> the modelled device cost is the uncontended
        // single-stream compute, not whatever contention happens to be.
        let (outs, exclusive_ms) = rt.exec_exclusive(&self.artifact, &[(*q).clone(), (*t).clone()])?;
        let chunk_offset = input.get("chunk_offset")?.as_usize()?;
        Ok(TaskOutput {
            value: Value::obj(vec![
                ("chunk_offset", Value::num(chunk_offset as f64)),
                ("min_dist2", tensor_to_json(&outs[0])),
                ("argmin", tensor_to_json(&outs[1])),
            ]),
            modelled_ms: Some(exclusive_ms),
        })
    }
}

/// Full Table-2-style project driver: distribute the (query window ×
/// train chunk) grid across N simulated devices and fold the results.
/// Shared by `examples/knn_mnist.rs` and `benches/table2_knn.rs`.
pub mod project {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use anyhow::Result;

    use super::KnnChunkTask;
    use crate::coordinator::{Distributor, Framework};
    use crate::data::Dataset;
    use crate::runtime::SharedRuntime;
    use crate::store::{Scheduler as _, StoreConfig};
    use crate::transport::{local, Conn, LinkModel};
    use crate::util::json::Value;
    use crate::worker::{DeviceProfile, Worker, WorkerReport};

    #[derive(Clone)]
    pub struct KnnRunConfig {
        pub n_queries: usize,
        pub n_train: usize,
        pub clients: usize,
        pub profile: DeviceProfile,
        pub link: LinkModel,
        pub sleep_on_link: bool,
        /// Use the small artifact (20x200) instead of 100x2000.
        pub small: bool,
    }

    pub struct KnnRunResult {
        pub elapsed_s: f64,
        pub predictions: Vec<usize>,
        pub accuracy: f64,
        pub reports: Vec<WorkerReport>,
        pub redistributions: u64,
        pub tickets: usize,
    }

    /// The per-ticket compute cost modelled for device padding: measured
    /// once on the reference host, scaled by (q*c) work, then divided by
    /// the profile speed inside the worker.
    pub fn run(rt: SharedRuntime, queries: &Dataset, train: &Dataset, cfg: &KnnRunConfig) -> Result<KnnRunResult> {
        let def = if cfg.small { KnnChunkTask::small() } else { KnnChunkTask::standard() };
        let (qrows, crows) = (def.query_rows, def.chunk_rows);
        anyhow::ensure!(cfg.n_queries % qrows == 0, "queries {} % {qrows} != 0", cfg.n_queries);
        anyhow::ensure!(cfg.n_train % crows == 0, "train {} % {crows} != 0", cfg.n_train);
        rt.load(&def.artifact)?; // compile before timing

        let fw = Framework::builder()
            .store_config(StoreConfig {
                requeue_after_ms: 10_000,
                min_redistribute_ms: 1_000,
                requeue_on_error: true,
                ..StoreConfig::default()
            })
            .build();
        for (w, start) in (0..cfg.n_queries).step_by(qrows).enumerate() {
            fw.datasets().register(&format!("q{w}"), queries.rows_matrix(start, qrows));
        }
        for (c, start) in (0..cfg.n_train).step_by(crows).enumerate() {
            fw.datasets().register(&format!("chunk{c}"), train.rows_matrix(start, crows));
        }
        let task = fw.create_task(Arc::new(if cfg.small {
            KnnChunkTask::small()
        } else {
            KnnChunkTask::standard()
        }));
        let mut payloads = Vec::new();
        for w in 0..cfg.n_queries / qrows {
            for c in 0..cfg.n_train / crows {
                payloads.push(def.ticket(&format!("q{w}"), &format!("chunk{c}"), c * crows));
            }
        }
        let n_tickets = payloads.len();
        task.calculate(payloads);

        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(cfg.link, cfg.sleep_on_link);
        dist.serve(Box::new(listener));
        let stop = Arc::new(AtomicBool::new(false));
        let t0 = std::time::Instant::now();
        let workers: Vec<_> = (0..cfg.clients)
            .map(|i| {
                let connector = connector.clone();
                let registry = fw.registry_snapshot();
                let stop = Arc::clone(&stop);
                let rt = rt.clone();
                let profile = cfg.profile.clone();
                std::thread::spawn(move || {
                    let mut w =
                        Worker::new(&format!("client{i}"), profile, registry).with_runtime(rt);
                    w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
                })
            })
            .collect();

        let results = task.block();
        let elapsed_s = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::SeqCst);
        let reports = workers.into_iter().map(|w| w.join().expect("worker")).collect();

        // Fold (min, argmin): results arrive ordered by ticket index =
        // (query window, chunk) row-major.
        let mut acc = vec![(f32::INFINITY, 0usize); cfg.n_queries];
        let folds_per_window = cfg.n_train / crows;
        for (i, r) in results.iter().enumerate() {
            let window = i / folds_per_window;
            let offset = r.get("chunk_offset")?.as_usize()?;
            let mins = crate::tasks::tensor_from_json(r.get("min_dist2")?)?;
            let argmins = crate::tasks::tensor_from_json(r.get("argmin")?)?;
            crate::runtime::tensor::fold_min_argmin(
                &mut acc[window * qrows..(window + 1) * qrows],
                mins.data(),
                argmins.data(),
                offset,
            );
        }
        let predictions: Vec<usize> = acc.iter().map(|(_, i)| train.labels[*i]).collect();
        let correct = predictions
            .iter()
            .zip(&queries.labels)
            .filter(|(p, l)| p == l)
            .count();
        let _ = Value::Null;
        Ok(KnnRunResult {
            elapsed_s,
            accuracy: correct as f64 / cfg.n_queries as f64,
            predictions,
            reports,
            redistributions: fw.store().progress(None).redistributions,
            tickets: n_tickets,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::test_support::FakeContext;

    #[test]
    fn dataset_refs_extracted() {
        let t = KnnChunkTask::standard();
        let payload = t.ticket("q0", "chunk3", 6000);
        assert_eq!(t.dataset_refs(&payload), vec!["q0".to_string(), "chunk3".to_string()]);
        assert_eq!(payload.get("chunk_offset").unwrap().as_usize().unwrap(), 6000);
    }

    #[test]
    fn missing_dataset_is_an_error() {
        let t = KnnChunkTask::small();
        let mut ctx = FakeContext::default();
        let payload = t.ticket("q", "c", 0);
        assert!(t.execute(&payload, &mut ctx).is_err());
    }
}
