//! Task definitions — the code the paper ships to browsers.
//!
//! In Sashimi a task is a JavaScript file the browser downloads and
//! `eval()`s.  Rust cannot load code over the wire, so tasks are
//! compiled in and selected *by name*: the worker still performs the
//! TaskRequest/TaskCode exchange (and pays the modelled download bytes,
//! and caches the "code" in its LRU exactly like a browser), but the
//! implementation comes from a [`Registry`] both sides share.  DESIGN.md
//! §2 documents this substitution.
//!
//! Built-in tasks:
//! * [`is_prime::IsPrimeTask`] — the paper's appendix sample project;
//! * [`knn::KnnChunkTask`] — Table 2's MNIST nearest-neighbour workload;
//! * [`train::ConvFwdTask`] / [`train::ConvGradTask`] — the hybrid
//!   algorithm's client-side work units (Fig 5);
//! * [`train::GradTask`] — the MLitB baseline's full-gradient work unit;
//! * [`sweep::SweepTask`] — a hyperparameter-sweep fan-out (deterministic
//!   surrogate loss), the churn soak's second workload.

pub mod is_prime;
pub mod knn;
pub mod sweep;
pub mod train;

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::runtime::{SharedRuntime, Tensor};
use crate::util::json::Value;

/// What a task execution produces: the result value (returned to the
/// server) and an optional *modelled* compute time.  `None` means "use
/// the measured execution time" — the worker pads either to
/// `ms / profile.speed` to emulate the device (DESIGN.md §7).
pub struct TaskOutput {
    pub value: Value,
    pub modelled_ms: Option<f64>,
}

impl TaskOutput {
    pub fn new(value: Value) -> TaskOutput {
        TaskOutput { value, modelled_ms: None }
    }
}

/// Services a task can use while executing on a worker: dataset fetch
/// (through the worker's LRU cache and the wire) and the XLA runtime.
pub trait TaskContext {
    /// Fetch a dataset tensor by key; cached per the paper's browser GC.
    fn dataset(&mut self, key: &str) -> Result<Arc<Tensor>>;
    /// The PJRT runtime for artifact execution.
    fn runtime(&self) -> Result<&SharedRuntime>;
}

/// A distributable task (the paper's TaskBase subclass).
pub trait TaskDef: Send + Sync {
    fn name(&self) -> &str;
    /// Simulated size of the task's code blob (download accounting).
    fn code_bytes(&self) -> usize {
        4096
    }
    /// Dataset keys this ticket needs (step 4 of the browser loop).
    fn dataset_refs(&self, input: &Value) -> Vec<String> {
        let _ = input;
        Vec::new()
    }
    /// Run the task against one ticket's divided argument.
    fn execute(&self, input: &Value, ctx: &mut dyn TaskContext) -> Result<TaskOutput>;
}

/// Name -> implementation map shared by framework, distributor, workers.
#[derive(Default, Clone)]
pub struct Registry {
    map: BTreeMap<String, Arc<dyn TaskDef>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, def: Arc<dyn TaskDef>) {
        self.map.insert(def.name().to_string(), def);
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn TaskDef>> {
        self.map
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("task {name:?} not registered"))
    }

    pub fn names(&self) -> Vec<String> {
        self.map.keys().cloned().collect()
    }
}

/// Server-side dataset store (the HTTPServer's dataset API).  Tensors
/// are registered by key; the wire encoding (base64 of LE f32) is
/// produced lazily and cached because big chunks are requested by every
/// worker.
#[derive(Default)]
pub struct DatasetStore {
    tensors: Mutex<HashMap<String, Arc<Tensor>>>,
    encoded: Mutex<HashMap<String, Arc<(Vec<usize>, String)>>>,
}

impl DatasetStore {
    pub fn new() -> DatasetStore {
        DatasetStore::default()
    }

    pub fn register(&self, key: &str, t: Tensor) {
        self.tensors.lock().unwrap().insert(key.to_string(), Arc::new(t));
        self.encoded.lock().unwrap().remove(key); // invalidate
    }

    pub fn get(&self, key: &str) -> Result<Arc<Tensor>> {
        self.tensors
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("dataset {key:?} not registered"))
    }

    /// (shape, base64) wire form, cached.
    pub fn encoded(&self, key: &str) -> Result<Arc<(Vec<usize>, String)>> {
        if let Some(e) = self.encoded.lock().unwrap().get(key) {
            return Ok(e.clone());
        }
        let t = self.get(key)?;
        let enc = Arc::new((
            t.shape().to_vec(),
            crate::util::base64::encode_f32(t.data()),
        ));
        self.encoded.lock().unwrap().insert(key.to_string(), enc.clone());
        Ok(enc)
    }

    /// Drop a dataset and its cached wire encoding.  The dist trainers
    /// evict round-parameter blobs once their round is complete so long
    /// runs don't accumulate one |θ| copy (plus its base64) per round.
    pub fn remove(&self, key: &str) {
        self.tensors.lock().unwrap().remove(key);
        self.encoded.lock().unwrap().remove(key);
    }

    pub fn keys(&self) -> Vec<String> {
        self.tensors.lock().unwrap().keys().cloned().collect()
    }
}

/// Helpers for tensors embedded in JSON payloads (the paper's base64
/// model-file convention applied to the wire).
pub fn tensor_to_json(t: &Tensor) -> Value {
    Value::obj(vec![
        ("shape", Value::arr(t.shape().iter().map(|&d| Value::num(d as f64)))),
        ("b64", Value::str(crate::util::base64::encode_f32(t.data()))),
    ])
}

pub fn tensor_from_json(v: &Value) -> Result<Tensor> {
    let shape = v.get("shape")?.as_usize_vec()?;
    let data = crate::util::base64::decode_f32(v.get("b64")?.as_str()?)?;
    Tensor::new(shape, data)
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// A context with preloaded datasets and no runtime.
    #[derive(Default)]
    pub struct FakeContext {
        pub datasets: HashMap<String, Arc<Tensor>>,
        pub fetches: Vec<String>,
    }

    impl TaskContext for FakeContext {
        fn dataset(&mut self, key: &str) -> Result<Arc<Tensor>> {
            self.fetches.push(key.to_string());
            self.datasets
                .get(key)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("no dataset {key:?}"))
        }

        fn runtime(&self) -> Result<&SharedRuntime> {
            anyhow::bail!("no runtime in FakeContext")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        let mut r = Registry::new();
        r.register(Arc::new(is_prime::IsPrimeTask));
        assert!(r.get("is_prime").is_ok());
        assert!(r.get("nope").is_err());
        assert_eq!(r.names(), vec!["is_prime".to_string()]);
    }

    #[test]
    fn dataset_store_roundtrip() {
        let ds = DatasetStore::new();
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        ds.register("m", t.clone());
        assert_eq!(*ds.get("m").unwrap(), t);
        let enc = ds.encoded("m").unwrap();
        assert_eq!(enc.0, vec![2, 2]);
        let back = crate::util::base64::decode_f32(&enc.1).unwrap();
        assert_eq!(back, t.data());
        // Cache hit returns the same Arc.
        assert!(Arc::ptr_eq(&enc, &ds.encoded("m").unwrap()));
        assert!(ds.get("x").is_err());
    }

    #[test]
    fn dataset_store_remove_evicts_tensor_and_encoding() {
        let ds = DatasetStore::new();
        ds.register("r0", Tensor::new(vec![1], vec![3.0]).unwrap());
        let _ = ds.encoded("r0").unwrap();
        ds.remove("r0");
        assert!(ds.get("r0").is_err());
        assert!(ds.encoded("r0").is_err());
        ds.remove("never-registered"); // idempotent
        assert!(ds.keys().is_empty());
    }

    #[test]
    fn tensor_json_roundtrip() {
        let t = Tensor::new(vec![3], vec![0.5, -1.5, 2.0]).unwrap();
        let v = tensor_to_json(&t);
        assert_eq!(tensor_from_json(&v).unwrap(), t);
    }
}
