//! The paper's appendix sample: IsPrimeTask / PrimeListMakerProject.
//!
//! `examples/prime_list.rs` reproduces Source Code 1–3 with the Rust
//! API; this is the task half (`is_prime_task.js` + `is_prime.js`).

use anyhow::Result;

use super::{TaskContext, TaskDef, TaskOutput};
use crate::util::json::Value;

pub struct IsPrimeTask;

/// `is_prime.js`: trial division (the external static code file).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3u64;
    while d * d <= n {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

impl TaskDef for IsPrimeTask {
    fn name(&self) -> &str {
        "is_prime"
    }

    fn code_bytes(&self) -> usize {
        // is_prime_task.js + is_prime.js, roughly.
        700
    }

    fn execute(&self, input: &Value, _ctx: &mut dyn TaskContext) -> Result<TaskOutput> {
        let candidate = input.get("candidate")?.as_u64()?;
        Ok(TaskOutput::new(Value::obj(vec![(
            "is_prime",
            Value::Bool(is_prime(candidate)),
        )])))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::test_support::FakeContext;

    #[test]
    fn primality_reference_values() {
        let primes: Vec<u64> =
            (1..=50).filter(|&n| is_prime(n)).collect();
        assert_eq!(primes, vec![2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47]);
        assert!(!is_prime(0) && !is_prime(1));
        assert!(is_prime(7919));
        assert!(!is_prime(7917));
    }

    #[test]
    fn task_contract() {
        let t = IsPrimeTask;
        let mut ctx = FakeContext::default();
        let out = t
            .execute(&Value::obj(vec![("candidate", Value::num(97.0))]), &mut ctx)
            .unwrap();
        assert_eq!(out.value.get("is_prime").unwrap().as_bool().unwrap(), true);
        let out = t
            .execute(&Value::obj(vec![("candidate", Value::num(98.0))]), &mut ctx)
            .unwrap();
        assert_eq!(out.value.get("is_prime").unwrap().as_bool().unwrap(), false);
        // Malformed input is an error (becomes an error report upstream).
        assert!(t.execute(&Value::Null, &mut ctx).is_err());
    }
}
