//! `sashimi` — leader/worker CLI.
//!
//! Subcommands:
//! * `serve`   — run the Distributor over TCP with the built-in task
//!   registry (prime + kNN tasks) and a synthetic-MNIST dataset API;
//!   prints the control console periodically.
//! * `worker`  — join a server as a browser-node (`--connect host:port`,
//!   `--profile desktop|tablet|native`, `--speed x.y`).
//! * `prime`   — the appendix's PrimeListMakerProject, distributed over
//!   in-process workers (see also examples/prime_list.rs).
//! * `train`   — standalone Sukiyaki training (`--engine xla|naive|jnp`).
//! * `hybrid` / `mlitb` / `hesync` — the §4 distributed algorithms.
//! * `info`    — artifact manifest summary.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Result};

use sashimi::coordinator::{console, Distributor, Framework, Gateway, GatewayConfig};
use sashimi::data;
use sashimi::data::loader::BatchLoader;
use sashimi::dist::{self, Cluster, ClusterConfig};
use sashimi::nn::{NativeEngine, TrainEngine, XlaEngine};
use sashimi::runtime::Runtime;
use sashimi::store::{Scheduler, StoreConfig, WalConfig, WalStore};
use sashimi::tasks::{self, is_prime::IsPrimeTask};
use sashimi::transport::tcp::TcpConn;
use sashimi::transport::ws::WsConn;
use sashimi::transport::{Conn, LinkModel};
use sashimi::util::cli::Args;
use sashimi::util::json::Value;
use sashimi::util::rng::SplitMix64;
use sashimi::worker::{DeviceProfile, Worker};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("serve") => serve(args),
        Some("worker") => worker(args),
        Some("prime") => prime(args),
        Some("train") => train(args),
        Some("hybrid") | Some("mlitb") | Some("hesync") => dist_train(args),
        Some("info") => info(args),
        other => {
            if other.is_some() {
                eprintln!("unknown subcommand {other:?}\n");
            }
            println!(
                "usage: sashimi <serve|worker|prime|train|hybrid|mlitb|hesync|info> [--flags]\n\
                 \n\
                 serve   --port 7070 [--ws-port 7071] [--heartbeat-ms 10000] [--state-dir DIR] [--replication 1] [--quorum 2] [--knn-queries 100] [--knn-train 2000]\n\
                 worker  --connect 127.0.0.1:7070 | --connect ws://host:7071/ [--profile native|desktop|tablet] [--speed X] [--prefetch N]\n\
                 prime   [--limit 10000] [--workers 2]\n\
                 train   [--engine xla|naive|jnp] [--net cifar|mnist] [--steps 20] [--data 2000]\n\
                 hybrid  [--net mnist] [--clients 2] [--rounds 3] (also mlitb, hesync)\n\
                 info"
            );
            Ok(())
        }
    }
}

fn profile_from(args: &Args) -> Result<DeviceProfile> {
    let p = args.str_or("profile", "native");
    let mut prof = match p.as_str() {
        "native" => DeviceProfile::native(),
        "desktop" => DeviceProfile::desktop(),
        "tablet" => DeviceProfile::tablet(),
        other => bail!("unknown profile {other:?}"),
    };
    if let Some(s) = args.get("speed") {
        let name = prof.name.clone();
        prof = DeviceProfile::with_speed(&name, s.parse()?);
    }
    Ok(prof)
}

fn serve(args: &Args) -> Result<()> {
    let port = args.usize_or("port", 7070)?;
    // The WebSocket listener rides one port up by default, so `serve
    // --port 7070` is reachable both from legacy TCP workers (7070) and
    // from a browser / websocat (ws://host:7071/).
    let ws_port = args.usize_or("ws-port", port + 1)?;
    let heartbeat_ms = args.u64_or("heartbeat-ms", 10_000)?;
    let nq = args.usize_or("knn-queries", 100)?;
    let nt = args.usize_or("knn-train", 2000)?;
    let state_dir = args.get("state-dir").map(String::from);
    // --replication/--quorum: quorum result verification (DESIGN.md
    // §2.8).  The default R = 1 is the bit-exact legacy
    // first-result-wins store; at R > 1 tickets complete on Q matching
    // results from distinct clients and minority voters lose
    // reputation.  Workers need no flag — the wire is unchanged.
    let replication = args.usize_or("replication", 1)? as u32;
    let quorum = args.usize_or("quorum", (replication as usize).min(2))? as u32;
    args.reject_unknown()?;
    let store_cfg = StoreConfig { replication, quorum, ..StoreConfig::default() };

    let mut builder = Framework::builder()
        .store_config(store_cfg.clone())
        .register(Arc::new(IsPrimeTask))
        .register(Arc::new(tasks::knn::KnnChunkTask::standard()));
    // --state-dir: durable tickets.  Restart-with-recovery is this same
    // command line again — WalStore replays checkpoint + log tail and the
    // coordinator resumes exactly where it crashed (DESIGN.md §2.2).
    let mut recovered_live = 0usize;
    if let Some(dir) = &state_dir {
        let wal = WalStore::open(dir, store_cfg.clone(), WalConfig::default())?;
        let p = wal.progress(None);
        recovered_live = p.pending + p.in_flight;
        if p.total > 0 {
            println!(
                "recovered {} tickets from {dir}: {} waiting, {} in flight, {} executed",
                p.total, p.pending, p.in_flight, p.done
            );
        }
        builder = builder.scheduler(Arc::new(wal));
    }
    let fw = builder.build();

    // Dataset APIs: synthetic MNIST for the kNN workload.
    let train = data::mnist_train(nt.max(2000), 1);
    let test = data::mnist_test(nq.max(100), 2);
    fw.datasets().register("knn_train_0", train.rows_matrix(0, 2000));
    fw.datasets().register("knn_queries_0", test.rows_matrix(0, 100));

    // Enqueue a kNN project so joining workers have work — unless the
    // state dir carried *live* (waiting or in-flight) tickets through
    // the restart; a fully executed recovered project gets fresh work.
    if recovered_live == 0 {
        let knn = tasks::knn::KnnChunkTask::standard();
        let task = fw.create_task(Arc::new(tasks::knn::KnnChunkTask::standard()));
        task.calculate(vec![knn.ticket("knn_queries_0", "knn_train_0", 0)]);
    }

    let dist = Distributor::new(&fw);
    // One epoll reactor carries both listeners: JSON-lines TCP for
    // legacy workers, WebSocket for browsers — same protocol, same
    // ticket pool, dead peers detected within 2× the heartbeat.
    let gw = Gateway::bind(
        &dist,
        GatewayConfig { heartbeat_ms },
        Some(&format!("0.0.0.0:{port}")),
        Some(&format!("0.0.0.0:{ws_port}")),
    )?;
    println!(
        "sashimi distributor on {} (tcp) + ws://{}/ (websocket)",
        gw.tcp_addr().unwrap_or_default(),
        gw.ws_addr().unwrap_or_default()
    );
    loop {
        sashimi::util::clock::sleep_ms(5000);
        println!("{}", console::render(&console::snapshot(&dist)));
        if dist.stopped() {
            break;
        }
    }
    gw.shutdown();
    Ok(())
}

fn worker(args: &Args) -> Result<()> {
    let addr = args.str_or("connect", "127.0.0.1:7070");
    let profile = profile_from(args)?;
    let max = args.u64_or("max-tickets", 0)?;
    // Adaptive prefetch ceiling; --prefetch 1 pins the legacy
    // one-ticket-per-round-trip protocol.
    let prefetch = args.usize_or("prefetch", sashimi::worker::DEFAULT_PREFETCH_CAP)?;
    args.reject_unknown()?;

    let mut registry = tasks::Registry::new();
    registry.register(Arc::new(IsPrimeTask));
    registry.register(Arc::new(tasks::knn::KnnChunkTask::standard()));
    let rt = sashimi::runtime::open_shared()?;
    // `ws://` joins through the WebSocket gateway port; a bare
    // host:port speaks the legacy JSON-lines wire.
    let is_ws = addr.starts_with("ws://");
    let scheme = if is_ws { "ws" } else { "tcp" };
    let mut w = Worker::new(&format!("{scheme}-{}", std::process::id()), profile, registry)
        .with_runtime(rt)
        .with_prefetch_cap(prefetch);
    if max > 0 {
        w.max_tickets = Some(max);
    }
    let stop = AtomicBool::new(false);
    let connect = |addr: &str| -> Result<Box<dyn Conn>> {
        Ok(if addr.starts_with("ws://") {
            Box::new(WsConn::connect(addr)?)
        } else {
            Box::new(TcpConn::connect(addr)?)
        })
    };
    let report = w.run(|| connect(&addr), &stop);
    println!(
        "worker done: {} tickets, {} errors, {} reloads, busy {:.1} ms",
        report.tickets_completed, report.errors_reported, report.reloads, report.busy_ms
    );
    Ok(())
}

fn prime(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 10_000)?;
    let n_workers = args.usize_or("workers", 2)?;
    args.reject_unknown()?;

    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (1..=limit).map(|i| Value::obj(vec![("candidate", Value::num(i as f64))])).collect(),
    );
    let dist = Distributor::new(&fw);
    let (listener, connector) = sashimi::transport::local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for i in 0..n_workers {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        joins.push(std::thread::spawn(move || {
            let mut w = Worker::new(&format!("w{i}"), DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        }));
    }
    let results = task.block();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let primes: Vec<usize> = results
        .iter()
        .enumerate()
        .filter(|(_, r)| r.opt("is_prime").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false))
        .map(|(i, _)| i + 1)
        .collect();
    for j in joins {
        let _ = j.join();
    }
    println!("{} primes up to {limit}; last: {:?}", primes.len(), primes.last());
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let engine_kind = args.str_or("engine", "xla");
    let net = args.str_or("net", "mnist");
    let steps = args.usize_or("steps", 20)?;
    let n_data = args.usize_or("data", 2000)?;
    args.reject_unknown()?;

    let rt = sashimi::runtime::open_shared()?;
    let spec = rt.net(&net)?.clone();
    let dataset =
        if net == "cifar" { data::cifar_train(n_data, 3) } else { data::mnist_train(n_data, 3) };
    let mut loader = BatchLoader::new(&dataset, spec.batch, 5);
    let mut rng = SplitMix64::new(42);
    let mut engine: Box<dyn TrainEngine> = match engine_kind.as_str() {
        "xla" => Box::new(XlaEngine::new(rt.clone(), &net, &mut rng)?),
        "jnp" => Box::new(
            XlaEngine::new(rt.clone(), &net, &mut rng)?
                .with_train_artifact(&format!("{net}_train_step_jnp")),
        ),
        "naive" => Box::new(NativeEngine::new(&spec, &mut rng)),
        other => bail!("unknown engine {other:?}"),
    };
    println!("training {net} with {} for {steps} steps", engine.name());
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y, _) = loader.next_batch();
        let loss = engine.train_batch(&x, &y)?;
        if step % 5 == 0 || step == steps - 1 {
            println!(
                "step {step:>4}  loss {loss:.4}  ({:.1} ms/step)",
                t0.elapsed().as_secs_f64() * 1e3 / (step + 1) as f64
            );
        }
    }
    let per = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
    println!("{} batches/min: {:.1}", engine.name(), 60_000.0 / per);
    Ok(())
}

fn dist_train(args: &Args) -> Result<()> {
    let algo = args.subcommand.clone().unwrap();
    let net = args.str_or("net", "mnist");
    let clients = args.usize_or("clients", 2)?;
    let rounds = args.u64_or("rounds", 3)?;
    args.reject_unknown()?;

    let rt = sashimi::runtime::open_shared()?;
    let dataset =
        if net == "cifar" { data::cifar_train(1000, 3) } else { data::mnist_train(1000, 3) };
    let cluster = Cluster::start(ClusterConfig::quick_test(&net, clients), rt, &dataset)?;
    let stats = match algo.as_str() {
        "hybrid" => {
            let r = dist::hybrid::train(
                &cluster,
                &dist::hybrid::HybridConfig { rounds, ..Default::default() },
            )?;
            println!("loss curve:\n{}", r.loss_curve.dump("hybrid"));
            r.stats
        }
        "mlitb" => dist::mlitb::train(&cluster, &dist::mlitb::MlitbConfig { rounds, seed: 11 })?.stats,
        "hesync" => {
            dist::he_sync::train(&cluster, &dist::he_sync::HeSyncConfig { rounds, seed: 11 })?.stats
        }
        _ => unreachable!(),
    };
    println!(
        "{}: clients={} conv {:.2} batches/s, fc {:.2} steps/s, loss {:.4}, {:.1} MB moved",
        stats.algorithm,
        stats.clients,
        stats.conv_batches_per_s,
        stats.fc_steps_per_s,
        stats.mean_loss_last_round,
        (stats.bytes.0 + stats.bytes.1) as f64 / 1e6
    );
    cluster.shutdown();
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("nets:");
    for (name, net) in &rt.manifest().nets {
        println!(
            "  {name}: {}x{}x{} batch={} params={}",
            net.input_hw,
            net.input_hw,
            net.input_c,
            net.batch,
            net.param_count()
        );
    }
    println!("artifacts:");
    for (name, sig) in &rt.manifest().artifacts {
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            sig.inputs.len(),
            sig.outputs.len(),
            sig.file.display()
        );
    }
    Ok(())
}
