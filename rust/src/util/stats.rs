//! Small statistics toolkit backing the bench harness and metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n<2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// A latency histogram for the soak metrics (DESIGN.md §2.5).
///
/// Keeps every sample exactly while the count stays within
/// `exact_cap`, so small-N percentiles are the textbook
/// linear-interpolated values ([`percentile`]).  Past the cap it spills
/// to power-of-two buckets (bucket 0 holds `[0,1)`, bucket `i >= 1`
/// holds `[2^(i-1), 2^i)`) and percentiles come from the cumulative
/// bucket walk, answered at the bucket midpoint — a bounded-memory
/// approximation with relative error < 50%, plenty for p50/p99 gates
/// over millisecond latencies.  `count/sum/min/max` stay exact in both
/// modes, and [`merge`](Histogram::merge) combines two histograms
/// (per-worker shards) without losing exactness unless it must.
#[derive(Debug, Clone)]
pub struct Histogram {
    exact: Option<Vec<f64>>,
    exact_cap: usize,
    /// `buckets[i]` counts samples in `[2^(i-1), 2^i)` (i = 0: `[0,1)`).
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Default exact-sample budget: 4096 samples (32 KiB) before
    /// spilling to buckets.
    pub fn new() -> Histogram {
        Self::with_exact_cap(4096)
    }

    pub fn with_exact_cap(exact_cap: usize) -> Histogram {
        Histogram {
            exact: Some(Vec::new()),
            exact_cap,
            buckets: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(x: f64) -> usize {
        // Bit length of floor(x): 0 for [0,1), 1 for [1,2), 2 for
        // [2,4), ... Negative samples (not expected for latencies)
        // clamp into bucket 0.
        let v = x.max(0.0) as u64;
        (64 - v.leading_zeros()) as usize
    }

    fn bucket_mid(i: usize) -> f64 {
        if i == 0 {
            0.5
        } else {
            // Midpoint of [2^(i-1), 2^i).
            1.5 * (1u64 << (i - 1)) as f64
        }
    }

    fn spill(&mut self) {
        if let Some(xs) = self.exact.take() {
            for x in xs {
                let b = Self::bucket_of(x);
                if self.buckets.len() <= b {
                    self.buckets.resize(b + 1, 0);
                }
                self.buckets[b] += 1;
            }
        }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        match &mut self.exact {
            Some(xs) if xs.len() < self.exact_cap => xs.push(x),
            _ => {
                self.spill();
                let b = Self::bucket_of(x);
                if self.buckets.len() <= b {
                    self.buckets.resize(b + 1, 0);
                }
                self.buckets[b] += 1;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Whether percentiles are still exact (no bucket spill happened).
    pub fn is_exact(&self) -> bool {
        self.exact.is_some()
    }

    /// p in [0,100]; 0.0 when empty.  Exact (linear interpolation)
    /// while un-spilled, bucket-midpoint approximation after, with the
    /// true min/max substituted at the extremes so p0/p100 are always
    /// exact.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if let Some(xs) = &self.exact {
            return percentile(xs, p);
        }
        if p <= 0.0 {
            return self.min();
        }
        if p >= 100.0 {
            return self.max();
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max()
    }

    /// Fold `other` into `self` (per-worker shards into a fleet
    /// total).  Exactness survives only if both sides are exact and the
    /// combined sample count fits the cap; otherwise both spill.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        let fits = match (&self.exact, &other.exact) {
            (Some(a), Some(b)) => a.len() + b.len() <= self.exact_cap,
            _ => false,
        };
        if fits {
            let b = other.exact.as_ref().unwrap();
            self.exact.as_mut().unwrap().extend_from_slice(b);
            return;
        }
        self.spill();
        // Other's samples as buckets (spilling a clone keeps `other`
        // untouched).
        let mut theirs = other.buckets.clone();
        if let Some(xs) = &other.exact {
            for &x in xs {
                let b = Self::bucket_of(x);
                if theirs.len() <= b {
                    theirs.resize(b + 1, 0);
                }
                theirs[b] += 1;
            }
        }
        if self.buckets.len() < theirs.len() {
            self.buckets.resize(theirs.len(), 0);
        }
        for (i, c) in theirs.into_iter().enumerate() {
            self.buckets[i] += c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    /// Small-N histograms answer the exact linear-interpolated
    /// percentiles — identical to the slice [`percentile`].
    #[test]
    fn histogram_small_n_percentiles_are_exact() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        let mut h = Histogram::new();
        for x in xs {
            h.record(x);
        }
        assert!(h.is_exact());
        assert_eq!(h.count(), 5);
        for p in [0.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), percentile(&xs, p), "p{p}");
        }
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 5.0);
        assert!((h.mean() - 3.0).abs() < 1e-12);
    }

    /// Past the exact cap the histogram spills to power-of-two buckets:
    /// count/sum/min/max stay exact, percentiles land in the right
    /// bucket (relative error < 50%), p0/p100 stay exact.
    #[test]
    fn histogram_spills_to_buckets_past_cap() {
        let mut h = Histogram::with_exact_cap(10);
        for i in 0..100u32 {
            h.record(i as f64); // 0..99
        }
        assert!(!h.is_exact());
        assert_eq!(h.count(), 100);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 99.0);
        assert!((h.sum() - 4950.0).abs() < 1e-9);
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(100.0), 99.0);
        let p50 = h.percentile(50.0); // true value 49.5; bucket [32,64) mid = 48
        assert!((p50 - 49.5).abs() / 49.5 < 0.5, "p50 approx {p50}");
        let p99 = h.percentile(99.0); // true 98.x; bucket [64,128) mid clamped to max
        assert!((60.0..=99.0).contains(&p99), "p99 approx {p99}");
    }

    /// Merging two exact shards under the cap stays exact; merging past
    /// the cap degrades gracefully and preserves count/sum/min/max.
    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for x in [1.0, 2.0, 3.0] {
            a.record(x);
        }
        for x in [4.0, 5.0] {
            b.record(x);
        }
        a.merge(&b);
        assert!(a.is_exact());
        assert_eq!(a.count(), 5);
        assert_eq!(a.percentile(50.0), 3.0);

        let mut big = Histogram::with_exact_cap(4);
        for x in [1.0, 2.0, 3.0] {
            big.record(x);
        }
        big.merge(&b); // 3 + 2 > cap 4: spills
        assert!(!big.is_exact());
        assert_eq!(big.count(), 5);
        assert_eq!(big.min(), 1.0);
        assert_eq!(big.max(), 5.0);
        assert!((big.sum() - 15.0).abs() < 1e-12);
        // Merging an empty histogram is a no-op either way.
        let before = big.count();
        big.merge(&Histogram::new());
        assert_eq!(big.count(), before);
    }

    /// Empty histograms answer zeros everywhere, like the slice fns.
    #[test]
    fn histogram_empty_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }
}
