//! Small statistics toolkit backing the bench harness and metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n<2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
