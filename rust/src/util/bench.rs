//! Mini-criterion: the measurement harness behind `cargo bench`.
//!
//! criterion is not in the offline crate set, so benches use this:
//! warm-up, fixed sample count, mean/σ/percentiles, and Markdown table /
//! series printers that emit the paper-shaped rows (Table 2, Table 4,
//! Fig 3, Fig 5) next to the paper's own numbers.

use std::time::Instant;

use super::stats;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples_ms: Vec<f64>,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        stats::mean(&self.samples_ms)
    }

    pub fn stddev_ms(&self) -> f64 {
        stats::stddev(&self.samples_ms)
    }

    pub fn min_ms(&self) -> f64 {
        stats::min(&self.samples_ms)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<40} mean {:>10.3} ms  σ {:>8.3} ms  min {:>10.3} ms  (n={})",
            self.name,
            self.mean_ms(),
            self.stddev_ms(),
            self.min_ms(),
            self.samples_ms.len()
        )
    }
}

/// Run `f` `warmup` times unmeasured, then `samples` times measured.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples_ms = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        samples_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let m = Measurement { name: name.to_string(), samples_ms };
    println!("{}", m.summary());
    m
}

/// Time a single long-running scenario (end-to-end drivers where a
/// sample *is* the experiment).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    let ms = t.elapsed().as_secs_f64() * 1e3;
    println!("{name:<40} {ms:>12.1} ms");
    (out, ms)
}

/// Markdown table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n### {}\n", self.title);
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!();
    }
}

/// Series printer for figure-shaped results (x, one or more y columns).
pub struct Series {
    title: String,
    x_label: String,
    y_labels: Vec<String>,
    points: Vec<(f64, Vec<f64>)>,
}

impl Series {
    pub fn new(title: &str, x_label: &str, y_labels: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_labels: y_labels.iter().map(|s| s.to_string()).collect(),
            points: Vec::new(),
        }
    }

    pub fn point(&mut self, x: f64, ys: &[f64]) {
        assert_eq!(ys.len(), self.y_labels.len());
        self.points.push((x, ys.to_vec()));
    }

    pub fn print(&self) {
        println!("\n### {} (series)\n", self.title);
        print!("{:>12}", self.x_label);
        for y in &self.y_labels {
            print!("{y:>18}");
        }
        println!();
        for (x, ys) in &self.points {
            print!("{x:>12.3}");
            for y in ys {
                print!("{y:>18.5}");
            }
            println!();
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let m = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.samples_ms.len(), 5);
        assert!(m.mean_ms() >= 0.0);
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["1".into()]);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn series_points() {
        let mut s = Series::new("fig", "clients", &["conv", "fc"]);
        s.point(1.0, &[1.0, 1.5]);
        s.point(2.0, &[2.0, 1.5]);
        s.print();
        assert_eq!(s.points.len(), 2);
    }
}
