//! Fixed-size thread pool (std-only; the offline crate set has no rayon
//! or tokio).  Used for parallel data generation and the MLitB baseline's
//! local gradient fan-out.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), handles }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).expect("worker died");
    }

    /// Map `f` over `items` on the pool, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("missing result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |x: i32| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn zero_threads_panics() {
        let _ = ThreadPool::new(0);
    }
}
