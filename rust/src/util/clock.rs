//! Time utilities: a process-wide millisecond clock and the device-speed
//! padding used to emulate heterogeneous clients on a 1-vCPU host
//! (DESIGN.md §7).

use std::time::{Duration, Instant};

use std::sync::OnceLock;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Milliseconds since first call (monotonic, process-wide).
pub fn now_ms() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Microseconds since first call.
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

pub fn sleep_ms(ms: u64) {
    std::thread::sleep(Duration::from_millis(ms));
}

/// Pads a real computation to a modelled duration: a worker with
/// `speed=0.14` that finished its real compute in 3 ms against a
/// modelled cost of 20 ms sleeps the remaining `20/0.14 - 3` ms.
///
/// This is how one host emulates the paper's OPTIPLEX-vs-Nexus-7 and
/// Node-vs-Firefox spread: the coordination, transport and numerics are
/// real; only the device-speed ratio is modelled.
pub struct PaddedTimer {
    start: Instant,
}

impl PaddedTimer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Real elapsed time so far.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Sleep until total elapsed == `modelled_ms / speed`; returns the
    /// padded duration actually reached (>= real elapsed).
    pub fn pad_to(&self, modelled_ms: f64, speed: f64) -> f64 {
        let target = modelled_ms / speed.max(1e-9);
        let real = self.elapsed_ms();
        if target > real {
            std::thread::sleep(Duration::from_secs_f64((target - real) / 1e3));
        }
        self.elapsed_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_is_monotone() {
        let a = now_ms();
        sleep_ms(2);
        let b = now_ms();
        assert!(b >= a + 1);
    }

    #[test]
    fn pad_reaches_target() {
        let t = PaddedTimer::start();
        let total = t.pad_to(20.0, 1.0);
        assert!(total >= 19.0, "padded to {total}");
    }

    #[test]
    fn pad_scales_with_speed() {
        let t = PaddedTimer::start();
        let total = t.pad_to(5.0, 0.5); // modelled 5 ms at half speed = 10 ms
        assert!(total >= 9.0, "padded to {total}");
    }

    #[test]
    fn pad_never_shortens() {
        let t = PaddedTimer::start();
        sleep_ms(10);
        let total = t.pad_to(1.0, 1.0); // target already passed
        assert!(total >= 10.0);
    }
}
