//! Time utilities: a process-wide millisecond clock, an injectable
//! [`Clock`] abstraction (wall time or simulator-advanced virtual
//! time), and the device-speed padding used to emulate heterogeneous
//! clients on a 1-vCPU host (DESIGN.md §7).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Milliseconds since first call (monotonic, process-wide).
pub fn now_ms() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Microseconds since first call.
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

pub fn sleep_ms(ms: u64) {
    std::thread::sleep(Duration::from_millis(ms));
}

/// An injectable time source (DESIGN.md §2.5).
///
/// The coordination layer never consults wall time directly for policy
/// decisions — redistribution windows, VCT timestamps, connect times,
/// worker backoff all read a `Clock`, so the same code runs in real
/// time ([`WallClock`], the default everywhere) or under a simulator
/// that advances time event-by-event ([`VirtualClock`]).  Ten minutes
/// of fleet churn then replay in milliseconds, deterministically.
pub trait Clock: Send + Sync {
    /// Milliseconds on this clock (monotone non-decreasing).
    fn now_ms(&self) -> u64;
    /// Park the caller for `ms` *of this clock's time* where that is
    /// meaningful (wall clock), or briefly yield (virtual clock — see
    /// [`VirtualClock`] on why virtual sleeps never advance time).
    fn sleep_ms(&self, ms: u64);
}

/// The process clock: [`now_ms`]/[`sleep_ms`] behind the [`Clock`]
/// trait.  Every production constructor defaults to this.
#[derive(Debug, Default, Clone, Copy)]
pub struct WallClock;

impl Clock for WallClock {
    fn now_ms(&self) -> u64 {
        now_ms()
    }

    fn sleep_ms(&self, ms: u64) {
        sleep_ms(ms)
    }
}

/// A clock the test/simulation harness advances explicitly.
///
/// Two deliberate properties, both load-bearing for determinism:
///
/// * `sleep_ms` does **not** advance virtual time.  Threaded workers
///   sleeping in their idle backoff would otherwise race each other
///   forward and nondeterministically expire redistribution windows;
///   only the owner of the clock (the simulator's event loop, or the
///   test body) moves time.
/// * `sleep_ms` does **not** block until the requested virtual instant.
///   A sleeper waiting for an advance that only happens after it wakes
///   would deadlock; instead the call takes a ~1 ms real nap (so
///   spinning pollers still yield the CPU) and returns.  Virtual
///   sleepers poll; virtual time only moves via [`advance`] /
///   [`advance_to`].
///
/// [`advance`]: VirtualClock::advance
/// [`advance_to`]: VirtualClock::advance_to
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: AtomicU64,
}

impl VirtualClock {
    /// A virtual clock pinned at t = 0 ms.
    pub fn new() -> VirtualClock {
        Self::at(0)
    }

    /// A virtual clock starting at `ms`.
    pub fn at(ms: u64) -> VirtualClock {
        VirtualClock { now: AtomicU64::new(ms) }
    }

    /// Move time forward by `ms`; returns the new now.
    pub fn advance(&self, ms: u64) -> u64 {
        self.now.fetch_add(ms, Ordering::SeqCst) + ms
    }

    /// Move time forward to the absolute instant `ms` (no-op if the
    /// clock is already there or past — virtual time never rewinds).
    pub fn advance_to(&self, ms: u64) -> u64 {
        self.now.fetch_max(ms, Ordering::SeqCst).max(ms)
    }
}

impl Clock for VirtualClock {
    fn now_ms(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep_ms(&self, _ms: u64) {
        // See the type docs: yield real CPU, never advance or wait on
        // virtual time.
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Pads a real computation to a modelled duration: a worker with
/// `speed=0.14` that finished its real compute in 3 ms against a
/// modelled cost of 20 ms sleeps the remaining `20/0.14 - 3` ms.
///
/// This is how one host emulates the paper's OPTIPLEX-vs-Nexus-7 and
/// Node-vs-Firefox spread: the coordination, transport and numerics are
/// real; only the device-speed ratio is modelled.
pub struct PaddedTimer {
    start: Instant,
}

impl PaddedTimer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Real elapsed time so far.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Sleep until total elapsed == `modelled_ms / speed`; returns the
    /// padded duration actually reached (>= real elapsed).
    pub fn pad_to(&self, modelled_ms: f64, speed: f64) -> f64 {
        let target = modelled_ms / speed.max(1e-9);
        let real = self.elapsed_ms();
        if target > real {
            std::thread::sleep(Duration::from_secs_f64((target - real) / 1e3));
        }
        self.elapsed_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_is_monotone() {
        let a = now_ms();
        sleep_ms(2);
        let b = now_ms();
        assert!(b >= a + 1);
    }

    #[test]
    fn pad_reaches_target() {
        let t = PaddedTimer::start();
        let total = t.pad_to(20.0, 1.0);
        assert!(total >= 19.0, "padded to {total}");
    }

    #[test]
    fn pad_scales_with_speed() {
        let t = PaddedTimer::start();
        let total = t.pad_to(5.0, 0.5); // modelled 5 ms at half speed = 10 ms
        assert!(total >= 9.0, "padded to {total}");
    }

    #[test]
    fn pad_never_shortens() {
        let t = PaddedTimer::start();
        sleep_ms(10);
        let total = t.pad_to(1.0, 1.0); // target already passed
        assert!(total >= 10.0);
    }

    #[test]
    fn wall_clock_tracks_process_clock() {
        let c = WallClock;
        let a = c.now_ms();
        c.sleep_ms(2);
        assert!(c.now_ms() >= a + 1);
    }

    #[test]
    fn virtual_clock_only_moves_when_advanced() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ms(), 0);
        c.sleep_ms(10_000); // returns promptly, moves nothing
        assert_eq!(c.now_ms(), 0);
        assert_eq!(c.advance(500), 500);
        assert_eq!(c.now_ms(), 500);
        assert_eq!(c.advance_to(400), 500, "never rewinds");
        assert_eq!(c.advance_to(900), 900);
        assert_eq!(c.now_ms(), 900);
    }

    #[test]
    fn virtual_clock_shares_across_threads() {
        use std::sync::Arc;
        let c: Arc<dyn Clock> = Arc::new(VirtualClock::at(7));
        let c2 = Arc::clone(&c);
        let h = std::thread::spawn(move || c2.now_ms());
        assert_eq!(h.join().unwrap(), 7);
    }
}
