//! Seeded property-test harness (proptest is not in the offline set).
//!
//! `check(name, cases, |rng| ...)` runs the property across `cases`
//! independently-seeded RNGs; a failure reports the exact case seed so
//! `check_seed(name, seed, f)` reproduces it deterministically.  No
//! shrinking — generators here are small enough to debug from the seed.

use super::rng::SplitMix64;

/// Run `f` across `cases` seeded RNGs; panic with the failing seed.
pub fn check<F: Fn(&mut SplitMix64) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    // Derive per-case seeds from the property name so different
    // properties never share streams.
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = SplitMix64::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name:?} failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Reproduce one case by seed.
pub fn check_seed<F: Fn(&mut SplitMix64) -> Result<(), String>>(name: &str, seed: u64, f: F) {
    let mut rng = SplitMix64::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property {name:?} failed on seed {seed:#x}: {msg}");
    }
}

/// Assertion helper usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // Count via a property with interior state is awkward across Fn;
        // just verify no panic across many cases.
        check("trivial", 100, |rng| {
            let v = rng.gen_range(10);
            if v < 10 {
                Ok(())
            } else {
                Err("range".into())
            }
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_differ_across_cases_and_names() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}
