//! Minimal JSON: parser + writer + access helpers.
//!
//! Stands in for serde_json (unavailable offline).  Covers the full JSON
//! grammar (RFC 8259) minus \u surrogate-pair edge pedantry, which the
//! manifest/model-file/wire formats never emit.  The paper's own formats
//! are JSON too (model files, §3.1), so this module is on the hot
//! metadata path and is fuzz-tested via util::proptest.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Numbers are f64 (JSON's own model); object keys are
/// sorted (BTreeMap) so serialisation is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- constructors ------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    pub fn f32s(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&v| Value::Num(v as f64)).collect())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer ------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like JSON.stringify does.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Ryū-style shortest repr via Rust's Display for f64 round-trips.
        let _ = write!(out, "{n}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    let b = s.as_bytes();
    let mut start = 0;
    let mut i = 0;
    // Bulk spans between escapable bytes (multi-MB base64 payloads copy
    // in one push_str — §Perf L3).
    while i < b.len() {
        let c = b[i];
        if c == b'"' || c == b'\\' || c < 0x20 {
            out.push_str(&s[start..i]);
            match c {
                b'"' => out.push_str("\\\""),
                b'\\' => out.push_str("\\\\"),
                b'\n' => out.push_str("\\n"),
                b'\r' => out.push_str("\\r"),
                b'\t' => out.push_str("\\t"),
                c => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
            }
            start = i + 1;
        }
        i += 1;
    }
    out.push_str(&s[start..]);
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            // Bulk path: copy the maximal span without quotes/escapes in
            // one memcpy-ish push_str.  Dataset payloads are multi-MB
            // base64 strings, so this span is usually the whole string
            // (EXPERIMENTS.md §Perf L3).
            let start = self.i;
            let mut j = self.i;
            while j < self.b.len() && self.b[j] != b'"' && self.b[j] != b'\\' && self.b[j] >= 0x20 {
                j += 1;
            }
            if j > start {
                s.push_str(std::str::from_utf8(&self.b[start..j])?);
                self.i = j;
            }
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multibyte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" -12.5e2 ").unwrap(), Value::Num(-1250.0));
        assert_eq!(Value::parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip_preserves_value() {
        let src = r#"{"model":"cifar","params":[0.5,-1,3.25],"meta":{"epoch":12,"done":false},"note":"日本語 \"quoted\""}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Value::Num(5.0).to_string(), "5");
        assert_eq!(Value::Num(5.5).to_string(), "5.5");
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("[1] x").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn f32_helpers() {
        let v = Value::f32s(&[1.0, -0.5]);
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, -0.5]);
    }

    #[test]
    fn control_chars_escaped() {
        let v = Value::Str("a\u{1}b".into());
        assert_eq!(v.to_string(), "\"a\\u0001b\"");
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }
}
