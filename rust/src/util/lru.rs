//! Byte-budgeted LRU cache.
//!
//! The paper's browser node caches task code and external datasets and
//! garbage-collects "on the basis of the least recently used algorithm"
//! (§2.1.2) because long runs otherwise exhaust browser memory.  The
//! worker uses this for exactly that purpose; capacity is in bytes so a
//! big dataset and a small task code blob compete for the same budget.

use std::collections::HashMap;
use std::hash::Hash;

/// LRU keyed by recency tick; eviction scans for the minimum tick, which
/// is O(n) per eviction but n (distinct cached objects per worker) is
/// small by construction — tasks and datasets, not tickets.
pub struct LruCache<K, V> {
    capacity_bytes: usize,
    used_bytes: usize,
    tick: u64,
    map: HashMap<K, Entry<V>>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

struct Entry<V> {
    value: V,
    bytes: usize,
    last_used: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(capacity_bytes: usize) -> Self {
        Self {
            capacity_bytes,
            used_bytes: 0,
            tick: 0,
            map: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits += 1;
                Some(&e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Insert `value` accounting `bytes` against the budget, evicting the
    /// least recently used entries until it fits.  Values larger than the
    /// whole budget are cached anyway (a browser must hold the dataset it
    /// is actively computing on) and evicted on the next pressure.
    pub fn put(&mut self, key: K, value: V, bytes: usize) {
        self.tick += 1;
        if let Some(old) = self.map.remove(&key) {
            self.used_bytes -= old.bytes;
        }
        while !self.map.is_empty() && self.used_bytes + bytes > self.capacity_bytes {
            self.evict_one();
        }
        self.used_bytes += bytes;
        self.map.insert(key, Entry { value, bytes, last_used: self.tick });
    }

    fn evict_one(&mut self) {
        if let Some(key) = self
            .map
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone())
        {
            if let Some(e) = self.map.remove(&key) {
                self.used_bytes -= e.bytes;
                self.evictions += 1;
            }
        }
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.used_bytes = 0;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn stats(&self) -> (u64, u64, u64) {
        (self.hits, self.misses, self.evictions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss() {
        let mut c: LruCache<&str, u32> = LruCache::new(100);
        c.put("a", 1, 10);
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.get(&"b"), None);
        assert_eq!(c.stats(), (1, 1, 0));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<&str, u32> = LruCache::new(30);
        c.put("a", 1, 10);
        c.put("b", 2, 10);
        c.put("c", 3, 10);
        c.get(&"a"); // a is now most recent; b is LRU
        c.put("d", 4, 10);
        assert!(c.contains(&"a"));
        assert!(!c.contains(&"b"));
        assert!(c.contains(&"c") && c.contains(&"d"));
    }

    #[test]
    fn replace_updates_budget() {
        let mut c: LruCache<&str, Vec<u8>> = LruCache::new(100);
        c.put("a", vec![0; 50], 50);
        c.put("a", vec![0; 20], 20);
        assert_eq!(c.used_bytes(), 20);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn oversized_entry_still_cached() {
        let mut c: LruCache<&str, u32> = LruCache::new(10);
        c.put("huge", 1, 1000);
        assert!(c.contains(&"huge"));
        c.put("next", 2, 5);
        assert!(!c.contains(&"huge")); // evicted under pressure
    }

    #[test]
    fn budget_never_exceeded_with_multiple_entries() {
        let mut c: LruCache<u32, u32> = LruCache::new(100);
        for i in 0..50 {
            c.put(i, i, 17);
        }
        assert!(c.used_bytes() <= 100 + 17); // at most one oversize overshoot
        assert!(c.len() <= 6);
    }

    #[test]
    fn clear_resets() {
        let mut c: LruCache<&str, u32> = LruCache::new(50);
        c.put("a", 1, 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }
}
