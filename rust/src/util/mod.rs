//! Substrate utilities the image's crate set does not provide.
//!
//! The deployment image has no crates.io access beyond the `xla` crate's
//! own dependency closure, so the pieces a framework would normally pull
//! in — JSON, base64, RNG, CLI parsing, an LRU cache, a bench harness, a
//! property-test harness, a thread pool — are implemented here and unit
//! tested like any other module (DESIGN.md §2).

pub mod base64;
pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod lockcheck;
pub mod log;
pub mod lru;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
