//! Leveled stderr logger (env-controlled via `SASHIMI_LOG`).
//!
//! Levels: error < warn < info < debug < trace.  Default is `info`.
//! The distributor and workers log through this; benches usually set
//! `SASHIMI_LOG=warn` to keep the tables clean.

use std::sync::atomic::{AtomicU8, Ordering};

use super::clock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised

fn current_level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v == u8::MAX {
        let lvl = std::env::var("SASHIMI_LOG").map(|s| Level::from_str(&s)).unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    match v {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= current_level()
}

pub fn log(l: Level, target: &str, msg: &str) {
    if enabled(l) {
        eprintln!("[{:>9.3}s {} {}] {}", clock::now_ms() as f64 / 1e3, l.tag(), target, msg);
    }
}

#[macro_export]
macro_rules! log_error { ($t:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, $t, &format!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, $t, &format!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, $t, &format!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, $t, &format!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }
}
