//! Ranked lock wrappers: the lock-ordering rules of DESIGN.md §2.6/§2.9
//! as an executable, debug-build runtime witness.
//!
//! Every long-lived lock in the store, WAL and coordinator is built as a
//! [`CheckedMutex`] / [`CheckedRwLock`] carrying a [`Rank`].  In release
//! builds the wrappers are passthroughs over `std::sync`; in debug
//! builds (`cfg(debug_assertions)` — the profile every tier-1 `cargo
//! test` run uses) each thread keeps a stack of the ranks it currently
//! holds, and a **blocking** acquire panics unless the new rank is
//! strictly greater than every rank already held.  Since "some thread
//! blocks while holding a lock another thread wants, and vice versa" is
//! exactly a rank cycle, a clean debug test suite is a machine-checked
//! proof that the suite exercised no deadlock-capable interleaving.
//!
//! `try_lock` / `try_read` / `try_write` are the escape hatch: they
//! record the acquired rank (so later blocking acquires still see it)
//! but never assert ordering, because a failed probe is dropped, not
//! waited on — the work-stealing scans in `sched.rs` / `wal.rs` probe
//! lower-ranked shards by design and cannot deadlock.
//!
//! # Rank table
//!
//! The order is the *observed* nesting of the code (verified by the
//! debug test suite), outermost first.  Note it deliberately corrects
//! the pre-PR-10 prose in DESIGN.md §2.6, which described the verify
//! mutex as outermost: in reality every sharded WAL operation holds its
//! stream lock(s) **across** the inner store call, so WAL streams are
//! the outermost store-side rank.
//!
//! | level | rank constructor          | lock                                            |
//! |-------|---------------------------|-------------------------------------------------|
//! | 0     | [`Rank::wal_flusher`]     | `WalStore.flusher` (group-commit thread handle) |
//! | 1.i   | [`Rank::wal_stream`]      | `WalStore.logs[i]`, ascending stream index      |
//! | 2     | [`Rank::verify_state`]    | `IndexedStore.verify` (quorum state)            |
//! | 3.i   | [`Rank::dispatch_shard`]  | `IndexedStore.dispatch[i]`, ascending shard     |
//! | 4.i   | [`Rank::body_stripe`]     | `IndexedStore.shards[i]` (ticket-body stripes)  |
//! | 5     | [`Rank::ledger_registry`] | `IndexedStore.ledgers` (task → ledger map)      |
//! | 6     | [`Rank::task_ledger`]     | `TaskLedger.state` (per-task results + condvar) |
//! | 7     | [`Rank::naive_inner`]     | `NaiveStore.inner` (reference store, one lock)  |
//! | 8.i   | coordinator ranks         | distributor `clients` / framework registry /    |
//! |       |                           | gateway thread handle — never held across a     |
//! |       |                           | store call, pinned innermost so holding one     |
//! |       |                           | over a blocking store acquire fails loudly      |
//!
//! Within a level the low 32 bits are the shard/stream index, so
//! ascending-index multi-acquisition (`WalStore::lock_streams`) is
//! legal and any descending blocking acquisition panics.
//!
//! The static half of the contract lives in `tools/pallas-lint`: raw
//! `std::sync` lock construction in `store/`, `coordinator/` and
//! `transport/` is a lint error, so new locks must come through here
//! and name a rank.

use std::cell::RefCell;
use std::fmt;
use std::mem::ManuallyDrop;
use std::sync::{
    Condvar, LockResult, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard,
    RwLockWriteGuard, TryLockError, TryLockResult, WaitTimeoutResult,
};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Ranks
// ---------------------------------------------------------------------------

/// A position in the global lock order: `(level << 32) | index`.
/// Compared as the packed key; the label only decorates panics.
#[derive(Clone, Copy)]
pub struct Rank {
    key: u64,
    label: &'static str,
}

impl Rank {
    const fn new(level: u32, index: u32, label: &'static str) -> Rank {
        Rank { key: ((level as u64) << 32) | index as u64, label }
    }

    /// `WalStore.flusher` — the group-commit thread's join handle.
    pub const fn wal_flusher() -> Rank {
        Rank::new(0, 0, "wal-flusher-handle")
    }

    /// `WalStore.logs[i]` — per-shard WAL stream writers, held across
    /// the inner store call (the outermost store-side rank); multi-
    /// stream ops acquire in ascending index order.
    pub const fn wal_stream(i: usize) -> Rank {
        Rank::new(1, i as u32, "wal-stream")
    }

    /// `IndexedStore.verify` — the quorum/reputation state, taken under
    /// the stream locks and held across a dispatch-shard acquire in
    /// `vote()`.
    pub const fn verify_state() -> Rank {
        Rank::new(2, 0, "verify-state")
    }

    /// `IndexedStore.dispatch[i]` — one blocking home acquire per
    /// operation; non-home shards are only ever `try_lock` probed.
    pub const fn dispatch_shard(i: usize) -> Rank {
        Rank::new(3, i as u32, "dispatch-shard")
    }

    /// `IndexedStore.shards[i]` — ticket-body stripe RwLocks.
    pub const fn body_stripe(i: usize) -> Rank {
        Rank::new(4, i as u32, "body-stripe")
    }

    /// `IndexedStore.ledgers` — the task → ledger registry RwLock,
    /// held (read) across per-ledger acquires in `snapshot()`.
    pub const fn ledger_registry() -> Rank {
        Rank::new(5, 0, "ledger-registry")
    }

    /// `TaskLedger.state` — per-task result ledgers (innermost store
    /// rank; the completion condvars wait on these).
    pub const fn task_ledger() -> Rank {
        Rank::new(6, 0, "task-ledger")
    }

    /// `NaiveStore.inner` — the reference store's single lock.
    pub const fn naive_inner() -> Rank {
        Rank::new(7, 0, "naive-inner")
    }

    /// `Distributor.clients` — per-client counters; never held across a
    /// store call (innermost band makes the reverse a loud failure).
    pub const fn distributor_clients() -> Rank {
        Rank::new(8, 0, "distributor-clients")
    }

    /// `Framework.registry` — task registry snapshots.
    pub const fn framework_registry() -> Rank {
        Rank::new(8, 1, "framework-registry")
    }

    /// `Gateway.thread` — the reactor thread's join handle.
    pub const fn gateway_thread() -> Rank {
        Rank::new(8, 2, "gateway-thread")
    }

    /// Ad-hoc rank for tests and fixtures.
    pub const fn test(level: u32, index: u32) -> Rank {
        Rank::new(level, index, "test")
    }

    fn level(self) -> u32 {
        (self.key >> 32) as u32
    }

    fn index(self) -> u32 {
        self.key as u32
    }
}

impl fmt::Debug for Rank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}.{}]", self.label, self.level(), self.index())
    }
}

// ---------------------------------------------------------------------------
// The witness (debug builds only)
// ---------------------------------------------------------------------------

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks this thread currently holds, in acquisition order (guards
    /// may drop out of order; release removes the last occurrence).
    static HELD: RefCell<Vec<Rank>> = const { RefCell::new(Vec::new()) };
}

/// Record an acquisition.  `blocking` acquires assert the rank is
/// strictly greater than everything already held — the ordering proof;
/// try-acquires only record, because a failed probe never waits.
#[cfg(debug_assertions)]
fn witness_acquire(rank: Rank, blocking: bool) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if blocking {
            if let Some(&worst) = held.iter().max_by_key(|r| r.key) {
                assert!(
                    rank.key > worst.key,
                    "lock rank inversion: blocking acquire of {rank:?} while holding {worst:?} \
                     (full stack: {:?}) — see util::lockcheck rank table",
                    &held[..],
                );
            }
        }
        held.push(rank);
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn witness_acquire(_rank: Rank, _blocking: bool) {}

#[cfg(debug_assertions)]
fn witness_release(rank: Rank) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|r| r.key == rank.key) {
            held.remove(pos);
        }
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn witness_release(_rank: Rank) {}

/// Number of checked locks the current thread holds (debug builds;
/// always 0 in release).  Test hook.
pub fn held_count() -> usize {
    #[cfg(debug_assertions)]
    {
        HELD.with(|h| h.borrow().len())
    }
    #[cfg(not(debug_assertions))]
    {
        0
    }
}

// ---------------------------------------------------------------------------
// CheckedMutex
// ---------------------------------------------------------------------------

/// A `std::sync::Mutex` that knows its place in the global lock order.
pub struct CheckedMutex<T: ?Sized> {
    rank: Rank,
    inner: Mutex<T>,
}

impl<T> CheckedMutex<T> {
    pub const fn new(rank: Rank, value: T) -> CheckedMutex<T> {
        CheckedMutex { rank, inner: Mutex::new(value) }
    }
}

impl<T: ?Sized> CheckedMutex<T> {
    /// Blocking acquire; panics (debug builds) on rank inversion.  The
    /// check runs *before* blocking, so an inversion fails loudly
    /// instead of deadlocking first.
    pub fn lock(&self) -> LockResult<CheckedMutexGuard<'_, T>> {
        witness_acquire(self.rank, true);
        match self.inner.lock() {
            Ok(g) => Ok(CheckedMutexGuard::wrap(self.rank, g)),
            Err(p) => Err(PoisonError::new(CheckedMutexGuard::wrap(self.rank, p.into_inner()))),
        }
    }

    /// Non-blocking probe: records the rank but never asserts order
    /// (the work-stealing escape hatch).
    pub fn try_lock(&self) -> TryLockResult<CheckedMutexGuard<'_, T>> {
        match self.inner.try_lock() {
            Ok(g) => {
                witness_acquire(self.rank, false);
                Ok(CheckedMutexGuard::wrap(self.rank, g))
            }
            Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            Err(TryLockError::Poisoned(p)) => {
                witness_acquire(self.rank, false);
                Err(TryLockError::Poisoned(PoisonError::new(CheckedMutexGuard::wrap(
                    self.rank,
                    p.into_inner(),
                ))))
            }
        }
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for CheckedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CheckedMutex").field("rank", &self.rank).field("inner", &self.inner).finish()
    }
}

/// Guard for [`CheckedMutex`]; pops its rank from the witness on drop.
pub struct CheckedMutexGuard<'a, T: ?Sized> {
    rank: Rank,
    inner: ManuallyDrop<MutexGuard<'a, T>>,
}

impl<'a, T: ?Sized> CheckedMutexGuard<'a, T> {
    fn wrap(rank: Rank, inner: MutexGuard<'a, T>) -> CheckedMutexGuard<'a, T> {
        CheckedMutexGuard { rank, inner: ManuallyDrop::new(inner) }
    }

    /// Dismantle without running `Drop` (the condvar handoff): the
    /// caller takes the raw guard and responsibility for the witness.
    fn into_parts(self) -> (Rank, MutexGuard<'a, T>) {
        let mut me = ManuallyDrop::new(self);
        // SAFETY: `me` is wrapped in ManuallyDrop so CheckedMutexGuard's
        // Drop never runs; the inner guard is moved out exactly once here.
        let g = unsafe { ManuallyDrop::take(&mut me.inner) };
        (me.rank, g)
    }
}

impl<T: ?Sized> std::ops::Deref for CheckedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T: ?Sized> std::ops::DerefMut for CheckedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T: ?Sized> Drop for CheckedMutexGuard<'_, T> {
    fn drop(&mut self) {
        witness_release(self.rank);
        // SAFETY: the guard is only constructed around a live inner
        // guard, `into_parts` skips this Drop entirely (ManuallyDrop
        // wrap), and Drop runs at most once — so the inner guard is
        // still initialised and is dropped exactly once.
        unsafe { ManuallyDrop::drop(&mut self.inner) };
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for CheckedMutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

// ---------------------------------------------------------------------------
// CheckedCondvar
// ---------------------------------------------------------------------------

/// A `Condvar` that waits on [`CheckedMutex`] guards.  The held rank is
/// popped for the duration of the wait (the mutex really is released)
/// and re-recorded — with the full ordering check — on wakeup.
pub struct CheckedCondvar {
    inner: Condvar,
}

impl CheckedCondvar {
    pub const fn new() -> CheckedCondvar {
        CheckedCondvar { inner: Condvar::new() }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    pub fn wait<'a, T>(
        &self,
        guard: CheckedMutexGuard<'a, T>,
    ) -> LockResult<CheckedMutexGuard<'a, T>> {
        let (rank, inner) = guard.into_parts();
        witness_release(rank);
        match self.inner.wait(inner) {
            Ok(g) => {
                witness_acquire(rank, true);
                Ok(CheckedMutexGuard::wrap(rank, g))
            }
            Err(p) => {
                witness_acquire(rank, true);
                Err(PoisonError::new(CheckedMutexGuard::wrap(rank, p.into_inner())))
            }
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: CheckedMutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(CheckedMutexGuard<'a, T>, WaitTimeoutResult)> {
        let (rank, inner) = guard.into_parts();
        witness_release(rank);
        match self.inner.wait_timeout(inner, dur) {
            Ok((g, timed_out)) => {
                witness_acquire(rank, true);
                Ok((CheckedMutexGuard::wrap(rank, g), timed_out))
            }
            Err(p) => {
                witness_acquire(rank, true);
                let (g, timed_out) = p.into_inner();
                Err(PoisonError::new((CheckedMutexGuard::wrap(rank, g), timed_out)))
            }
        }
    }
}

impl Default for CheckedCondvar {
    fn default() -> CheckedCondvar {
        CheckedCondvar::new()
    }
}

// ---------------------------------------------------------------------------
// CheckedRwLock
// ---------------------------------------------------------------------------

/// A `std::sync::RwLock` that knows its place in the global lock order.
/// Read and write acquires carry the same rank: the witness proves
/// ordering, not reader/writer exclusion (std already does that).
pub struct CheckedRwLock<T: ?Sized> {
    rank: Rank,
    inner: RwLock<T>,
}

impl<T> CheckedRwLock<T> {
    pub const fn new(rank: Rank, value: T) -> CheckedRwLock<T> {
        CheckedRwLock { rank, inner: RwLock::new(value) }
    }
}

impl<T: ?Sized> CheckedRwLock<T> {
    pub fn read(&self) -> LockResult<CheckedRwLockReadGuard<'_, T>> {
        witness_acquire(self.rank, true);
        match self.inner.read() {
            Ok(g) => Ok(CheckedRwLockReadGuard { rank: self.rank, inner: ManuallyDrop::new(g) }),
            Err(p) => Err(PoisonError::new(CheckedRwLockReadGuard {
                rank: self.rank,
                inner: ManuallyDrop::new(p.into_inner()),
            })),
        }
    }

    pub fn write(&self) -> LockResult<CheckedRwLockWriteGuard<'_, T>> {
        witness_acquire(self.rank, true);
        match self.inner.write() {
            Ok(g) => Ok(CheckedRwLockWriteGuard { rank: self.rank, inner: ManuallyDrop::new(g) }),
            Err(p) => Err(PoisonError::new(CheckedRwLockWriteGuard {
                rank: self.rank,
                inner: ManuallyDrop::new(p.into_inner()),
            })),
        }
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for CheckedRwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CheckedRwLock")
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Shared guard for [`CheckedRwLock`].
pub struct CheckedRwLockReadGuard<'a, T: ?Sized> {
    rank: Rank,
    inner: ManuallyDrop<RwLockReadGuard<'a, T>>,
}

impl<T: ?Sized> std::ops::Deref for CheckedRwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T: ?Sized> Drop for CheckedRwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        witness_release(self.rank);
        // SAFETY: constructed around a live inner guard and Drop runs at
        // most once, so the inner guard is dropped exactly once.
        unsafe { ManuallyDrop::drop(&mut self.inner) };
    }
}

/// Exclusive guard for [`CheckedRwLock`].
pub struct CheckedRwLockWriteGuard<'a, T: ?Sized> {
    rank: Rank,
    inner: ManuallyDrop<RwLockWriteGuard<'a, T>>,
}

impl<T: ?Sized> std::ops::Deref for CheckedRwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T: ?Sized> std::ops::DerefMut for CheckedRwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T: ?Sized> Drop for CheckedRwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        witness_release(self.rank);
        // SAFETY: constructed around a live inner guard and Drop runs at
        // most once, so the inner guard is dropped exactly once.
        unsafe { ManuallyDrop::drop(&mut self.inner) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn ascending_acquisition_is_clean() {
        let a = CheckedMutex::new(Rank::test(1, 0), 1u32);
        let b = CheckedMutex::new(Rank::test(1, 1), 2u32);
        let c = CheckedMutex::new(Rank::test(2, 0), 3u32);
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        let gc = c.lock().unwrap();
        assert_eq!(*ga + *gb + *gc, 6);
        assert_eq!(held_count(), if cfg!(debug_assertions) { 3 } else { 0 });
        drop((ga, gb, gc));
        assert_eq!(held_count(), 0);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "witness is debug-only")]
    #[should_panic(expected = "lock rank inversion")]
    fn descending_blocking_acquire_panics() {
        let outer = CheckedMutex::new(Rank::test(2, 0), ());
        let inner = CheckedMutex::new(Rank::test(1, 0), ());
        let _g = outer.lock().unwrap();
        let _bad = inner.lock().unwrap();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "witness is debug-only")]
    #[should_panic(expected = "lock rank inversion")]
    fn same_rank_reacquire_panics() {
        // Self-deadlock shape: same key is not strictly greater.
        let a = CheckedMutex::new(Rank::test(3, 7), ());
        let b = CheckedMutex::new(Rank::test(3, 7), ());
        let _g = a.lock().unwrap();
        let _bad = b.lock().unwrap();
    }

    #[test]
    fn try_lock_descending_never_panics() {
        let outer = CheckedMutex::new(Rank::test(2, 0), ());
        let inner = CheckedMutex::new(Rank::test(1, 0), 41u32);
        let _g = outer.lock().unwrap();
        // The work-stealing shape: a lower-ranked probe is fine...
        let stolen = inner.try_lock().unwrap();
        assert_eq!(*stolen + 1, 42);
        drop(stolen);
        // ...and a held probe still participates in later checks.
        let _again = inner.try_lock().unwrap();
    }

    #[test]
    fn out_of_order_drop_keeps_witness_balanced() {
        let a = CheckedMutex::new(Rank::test(1, 0), ());
        let b = CheckedMutex::new(Rank::test(2, 0), ());
        let c = CheckedMutex::new(Rank::test(3, 0), ());
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        drop(ga); // drop the outermost first
        let gc = c.lock().unwrap();
        drop((gb, gc));
        assert_eq!(held_count(), 0);
    }

    #[test]
    fn rwlock_orders_and_releases() {
        let stripe = CheckedRwLock::new(Rank::test(4, 0), vec![1, 2, 3]);
        let registry = CheckedRwLock::new(Rank::test(5, 0), 0u64);
        {
            let r = stripe.read().unwrap();
            let mut w = registry.write().unwrap();
            *w += r.len() as u64;
        }
        assert_eq!(held_count(), 0);
        assert_eq!(*registry.read().unwrap(), 3);
    }

    #[test]
    fn condvar_wait_releases_and_reacquires_rank() {
        let pair = Arc::new((CheckedMutex::new(Rank::test(6, 0), false), CheckedCondvar::new()));
        let pair2 = Arc::clone(&pair);
        let waker = thread::spawn(move || {
            let (m, cv) = &*pair2;
            *m.lock().unwrap() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
        drop(g);
        assert_eq!(held_count(), 0);
        waker.join().unwrap();
    }

    #[test]
    fn wait_timeout_times_out_and_rebalances() {
        let m = CheckedMutex::new(Rank::test(6, 0), ());
        let cv = CheckedCondvar::new();
        let g = m.lock().unwrap();
        let (g, timed_out) = cv.wait_timeout(g, Duration::from_millis(5)).unwrap();
        assert!(timed_out.timed_out());
        drop(g);
        assert_eq!(held_count(), 0);
    }

    #[test]
    fn contended_mutex_still_excludes() {
        // The wrapper must not weaken the lock itself.
        let m = Arc::new(CheckedMutex::new(Rank::test(1, 0), 0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&m);
            handles.push(thread::spawn(move || {
                for _ in 0..1000 {
                    *m.lock().unwrap() += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*m.lock().unwrap(), 4000);
    }
}
