//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Grammar: `sashimi <subcommand> [--key value]... [--flag]...`.
//! Typed getters with defaults; unknown-argument detection so typos fail
//! loudly instead of silently using a default.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    positional: Vec<String>,
    accessed: std::cell::RefCell<BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.accessed.borrow_mut().insert(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains(key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any provided --option was never consumed by the command.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.accessed.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(*k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown arguments: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --steps 100 --net cifar --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("steps", 1).unwrap(), 100);
        assert_eq!(a.str_or("net", "mnist"), "cifar");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --port=9000");
        assert_eq!(a.usize_or("port", 0).unwrap(), 9000);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("serve");
        assert_eq!(a.f64_or("speed", 1.0).unwrap(), 1.0);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --steps abc");
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn unknown_args_detected() {
        let a = parse("x --typo 3 --steps 7");
        let _ = a.usize_or("steps", 1);
        assert!(a.reject_unknown().is_err());
        let _ = a.get("typo");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --fast --real");
        assert!(a.flag("fast") && a.flag("real"));
    }

    #[test]
    fn negative_number_as_value() {
        // "--lr -0.5": '-0.5' does not start with '--' so it is a value.
        let a = parse("x --lr -0.5");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
    }
}
