//! Standard base64 (RFC 4648, with padding).
//!
//! The paper serialises model parameters as base64 inside JSON (§3.1:
//! "a model file wherein the parameters are encoded with base64 is
//! formatted in JSON ... exchanged among machines without rounding
//! errors").  `nn::model_file` uses this for f32 little-endian buffers.

use anyhow::{bail, Result};

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = (u32::from(b[0]) << 16) | (u32::from(b[1]) << 8) | u32::from(b[2]);
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[n as usize & 63] as char } else { '=' });
    }
    out
}

// Reverse lookup table: 255 = invalid, 254 = padding.
const REVERSE: [u8; 256] = {
    let mut t = [255u8; 256];
    let mut i = 0;
    while i < 64 {
        t[ALPHABET[i] as usize] = i as u8;
        i += 1;
    }
    t[b'=' as usize] = 254;
    t
};

pub fn decode(s: &str) -> Result<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 4 != 0 {
        bail!("base64 length {} not a multiple of 4", b.len());
    }
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    // Fast path for all full (non-padded) quads; only the final quad may
    // carry '='.  Table lookups, no per-chunk allocation — dataset
    // payloads run through here at ~GB/s (EXPERIMENTS.md §Perf L3).
    let n_quads = b.len() / 4;
    for (qi, chunk) in b.chunks_exact(4).enumerate() {
        let v0 = REVERSE[chunk[0] as usize];
        let v1 = REVERSE[chunk[1] as usize];
        let v2 = REVERSE[chunk[2] as usize];
        let v3 = REVERSE[chunk[3] as usize];
        if v0 < 64 && v1 < 64 && v2 < 64 && v3 < 64 {
            let n = ((v0 as u32) << 18) | ((v1 as u32) << 12) | ((v2 as u32) << 6) | v3 as u32;
            out.push((n >> 16) as u8);
            out.push((n >> 8) as u8);
            out.push(n as u8);
            continue;
        }
        // Slow path: padding is legal only in the last quad, only in the
        // last two symbols, and only as "xx==" or "xxx=".
        if qi != n_quads - 1 || v0 >= 64 || v1 >= 64 {
            if v0 == 255 || v1 == 255 || v2 == 255 && v2 != 254 || v3 == 255 && v3 != 254 {
                bail!("invalid base64 character");
            }
            bail!("malformed base64 padding");
        }
        match (v2, v3) {
            (254, 254) => {
                let n = ((v0 as u32) << 18) | ((v1 as u32) << 12);
                out.push((n >> 16) as u8);
            }
            (v2, 254) if v2 < 64 => {
                let n = ((v0 as u32) << 18) | ((v1 as u32) << 12) | ((v2 as u32) << 6);
                out.push((n >> 16) as u8);
                out.push((n >> 8) as u8);
            }
            (255, _) | (_, 255) => bail!("invalid base64 character"),
            _ => bail!("malformed base64 padding"),
        }
    }
    Ok(out)
}

/// f32 slice -> base64 of its little-endian bytes (the model-file format).
pub fn encode_f32(xs: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    encode(&bytes)
}

pub fn decode_f32(s: &str) -> Result<Vec<f32>> {
    let bytes = decode(s)?;
    if bytes.len() % 4 != 0 {
        bail!("decoded byte length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn f32_roundtrip_is_exact() {
        // The paper's whole point: no rounding errors across machines.
        let mut r = SplitMix64::new(9);
        let xs: Vec<f32> = (0..257).map(|_| r.uniform_f32(-1e6, 1e6)).collect();
        let back = decode_f32(&encode_f32(&xs)).unwrap();
        assert_eq!(xs.len(), back.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn special_values_roundtrip() {
        let xs = [0.0f32, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::MIN_POSITIVE, f32::NAN];
        let back = decode_f32(&encode_f32(&xs)).unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode("abc").is_err());
        assert!(decode("a=bc").is_err());
        assert!(decode("ab!c").is_err());
        assert!(decode_f32("Zg==").is_err()); // 1 byte, not multiple of 4
    }

    #[test]
    fn random_binary_roundtrip() {
        let mut r = SplitMix64::new(17);
        for len in [0usize, 1, 2, 3, 63, 64, 65, 1000] {
            let data: Vec<u8> = (0..len).map(|_| r.next_u64() as u8).collect();
            assert_eq!(decode(&encode(&data)).unwrap(), data, "len={len}");
        }
    }
}
