//! SplitMix64 deterministic RNG, bit-identical to `python/compile/prand.py`.
//!
//! Cross-language determinism is a load-bearing property: `aot.py` records
//! only (seed, shape, checksum) per golden artifact and the Rust tests
//! regenerate the exact input tensors from the same stream.  The pinned
//! known-answer vectors below are asserted by both test suites.

/// SplitMix64 — tiny, fast, and trivially portable across languages.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// One SplitMix64 step.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// f32 in `[lo, hi)` from the top 24 bits — matches prand.uniform_f32.
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        let z = self.next_u64();
        // (z >> 40) * 2^-24 computed in f64 then rounded to f32, exactly
        // as numpy does in prand.py.
        let u = ((z >> 40) as f64 * (1.0 / 16_777_216.0)) as f32;
        lo + u * (hi - lo)
    }

    /// A vector of uniform f32s (the golden-input generator).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32(lo, hi)).collect()
    }

    /// Unbiased integer in `[0, n)` (Lemire-style rejection).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Approximately standard-normal f32 (sum of 12 uniforms, CLT).
    /// Good enough for weight init / synthetic data; not for statistics.
    pub fn normal_f32(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.uniform_f32(0.0, 1.0);
        }
        s - 6.0
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-worker / per-shard RNGs).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

/// Generate the same array `prand.uniform_f32_array(seed, shape)` yields.
pub fn golden_input(seed: u64, n: usize) -> Vec<f32> {
    SplitMix64::new(seed).uniform_vec(n, -1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_known_answers() {
        // Same pinned vectors as python/tests/test_prand.py.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn uniform_is_deterministic_and_in_range() {
        let a = golden_input(42, 1000);
        let b = golden_input(42, 1000);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| (-1.0..1.0).contains(v)));
        let mean: f32 = a.iter().sum::<f32>() / 1000.0;
        assert!(mean.abs() < 0.06, "mean={mean}");
    }

    #[test]
    fn uniform_values_on_24bit_grid() {
        // Mirrors test_uniform_f32_pinned_values_for_rust on the py side.
        let xs = golden_input(1234, 4);
        for v in xs {
            let scaled = (v as f64 + 1.0) / 2.0 * 16_777_216.0;
            assert!((scaled - scaled.round()).abs() < 1e-6);
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10) as usize;
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = SplitMix64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_has_plausible_moments() {
        let mut r = SplitMix64::new(11);
        let xs: Vec<f32> = (0..4000).map(|_| r.normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.08, "mean={mean}");
        assert!((var - 1.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = SplitMix64::new(5);
        let mut a = r.fork();
        let mut b = r.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
