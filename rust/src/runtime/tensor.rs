//! Host-side dense f32 tensor.
//!
//! The whole stack is f32 end-to-end (labels travel as one-hot f32,
//! argmins come back as exact small-integer f32s — see model.py), so one
//! buffer type covers every artifact input/output and every native-engine
//! activation.

use anyhow::{bail, Result};

use crate::util::rng::SplitMix64;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Uniform [-scale, scale) fill from a SplitMix64 stream — the shared
    /// init convention for both engines (and for golden inputs at
    /// scale=1).
    pub fn uniform(shape: &[usize], rng: &mut SplitMix64, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.uniform_vec(n, -scale, scale) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor with {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Element-wise in-place axpy: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("axpy shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// L2 norm (for metrics / divergence guards).
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Compact checksum matching prand.checksum on the Python side.
    pub fn checksum(&self) -> (f64, f64) {
        let sum: f64 = self.data.iter().map(|&v| v as f64).sum();
        let abs: f64 = self.data.iter().map(|&v| (v as f64).abs()).sum();
        (sum, abs)
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Wire format: little-endian f32 bytes.
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn from_le_bytes(shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        if bytes.len() % 4 != 0 {
            bail!("byte length {} not a multiple of 4", bytes.len());
        }
        let data: Vec<f32> =
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        Tensor::new(shape, data)
    }
}

/// One (min, argmin) fold step used by the kNN reducer; lives here so it
/// is unit-testable away from the coordinator.
pub fn fold_min_argmin(
    acc: &mut [(f32, usize)],
    mins: &[f32],
    argmins: &[f32],
    chunk_offset: usize,
) {
    for (i, (m, a)) in mins.iter().zip(argmins).enumerate() {
        let idx = chunk_offset + *a as usize;
        if *m < acc[i].0 {
            acc[i] = (*m, idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked_construction() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_and_item() {
        let t = Tensor::new(vec![6], (0..6).map(|i| i as f32).collect()).unwrap();
        let t = t.reshape(&[2, 3]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.clone().reshape(&[4]).is_err());
        assert_eq!(Tensor::scalar(7.0).item().unwrap(), 7.0);
        assert!(t.item().is_err());
    }

    #[test]
    fn le_bytes_roundtrip() {
        let mut rng = SplitMix64::new(4);
        let t = Tensor::uniform(&[3, 5], &mut rng, 2.0);
        let back = Tensor::from_le_bytes(vec![3, 5], &t.to_le_bytes()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn axpy_and_norm() {
        let mut a = Tensor::filled(&[4], 1.0);
        let b = Tensor::filled(&[4], 2.0);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.data(), &[2.0; 4]);
        assert!((a.norm() - 4.0).abs() < 1e-6);
        let c = Tensor::filled(&[5], 0.0);
        assert!(a.axpy(1.0, &c).is_err());
    }

    #[test]
    fn fold_min_argmin_across_chunks() {
        let mut acc = vec![(f32::INFINITY, 0usize); 2];
        fold_min_argmin(&mut acc, &[5.0, 2.0], &[1.0, 3.0], 0);
        fold_min_argmin(&mut acc, &[3.0, 4.0], &[0.0, 1.0], 100);
        assert_eq!(acc[0], (3.0, 100));
        assert_eq!(acc[1], (2.0, 3));
    }

    #[test]
    fn uniform_deterministic() {
        let a = Tensor::uniform(&[10], &mut SplitMix64::new(1), 1.0);
        let b = Tensor::uniform(&[10], &mut SplitMix64::new(1), 1.0);
        assert_eq!(a, b);
    }
}
