//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! request path.
//!
//! This is the Rust half of the AOT bridge (see `python/compile/aot.py`):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → compile →
//! execute.  One `Runtime` per process; executables are compiled lazily
//! on first use and cached, so the hot path is literal-in / literal-out.
//!
//! Python is *never* involved here — the binary is self-contained once
//! `make artifacts` has run.

pub mod artifact;
pub mod tensor;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub use artifact::{default_artifacts_dir, ArtifactSig, Manifest, NetSpec};
pub use tensor::Tensor;

use crate::util::stats::Welford;

/// A compiled artifact plus its signature; cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Executable {
    sig: Arc<ArtifactSig>,
    exe: Arc<xla::PjRtLoadedExecutable>,
}

// SAFETY: the xla crate wraps raw PJRT pointers without auto traits, but
// the PJRT C API contract makes clients and loaded executables safe to
// use from multiple threads concurrently (execution is internally
// synchronised; buffers/literals here are created fresh per call and
// never shared across threads).  The coordinator relies on this to let
// worker threads execute artifacts in parallel.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}
// SAFETY: Runtime holds only the PJRT client (see above) and immutable
// compile options; the PJRT C API permits concurrent compilation and
// execution on one client, and no interior mutability is exposed.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Executable {
    /// Execute with shape-checked tensors; returns one tensor per
    /// declared output.  Rank-0 outputs come back as shape [] tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.sig.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.sig.name,
                self.sig.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, sig) in inputs.iter().zip(&self.sig.inputs) {
            if t.shape() != sig.shape.as_slice() {
                bail!(
                    "{}: input {:?} expects shape {:?}, got {:?}",
                    self.sig.name,
                    sig.name,
                    sig.shape,
                    t.shape()
                );
            }
            literals.push(tensor_to_literal(t)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.sig.name,
                self.sig.outputs.len(),
                parts.len()
            );
        }
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }

    pub fn sig(&self) -> &ArtifactSig {
        &self.sig
    }
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let bytes = t.to_le_bytes();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), &bytes)
        .map_err(Into::into)
}

fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    // All artifacts are f32-only by convention (enforced by aot.py).
    let data = l.to_vec::<f32>()?;
    Tensor::new(dims, data)
}

/// Per-artifact execution statistics (for the perf pass and console).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_ms: f64,
    pub compile_ms: f64,
    pub per_call: Welford,
}

/// The process-wide PJRT runtime: one CPU client, lazily compiled and
/// cached executables, execution statistics.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Executable>>,
    stats: Mutex<HashMap<String, ExecStats>>,
    /// Serialises `exec_exclusive` so the measured time is the
    /// *uncontended* single-stream cost — the quantity device-speed
    /// padding must scale (DESIGN.md §7).  On a 1-core host concurrent
    /// XLA executions would interleave anyway; the lock makes the
    /// timing deterministic instead of contention-dependent.
    exec_lock: Mutex<()>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
            exec_lock: Mutex::new(()),
        })
    }

    /// Open the default artifacts directory (walking up from cwd).
    pub fn open_default() -> Result<Runtime> {
        Self::new(&default_artifacts_dir()?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn net(&self, name: &str) -> Result<&NetSpec> {
        self.manifest.net(name)
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<Executable> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let sig = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            sig.file.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        let executable = Executable { sig: Arc::new(sig), exe: Arc::new(exe) };
        self.stats.lock().unwrap().entry(name.to_string()).or_default().compile_ms = compile_ms;
        crate::log_debug!("runtime", "compiled {name} in {compile_ms:.1} ms");
        self.cache.lock().unwrap().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// One-shot execute with stats accounting.
    pub fn exec(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let out = exe.run(inputs)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_ms += ms;
        s.per_call.push(ms);
        Ok(out)
    }

    /// Execute under the runtime's exclusive lock and return the
    /// *uncontended* execution time alongside the outputs.  Simulated
    /// devices (worker tasks, the hybrid server) use this time as the
    /// modelled compute cost so device-speed padding is independent of
    /// how many simulated devices currently share the host core.
    pub fn exec_exclusive(&self, name: &str, inputs: &[Tensor]) -> Result<(Vec<Tensor>, f64)> {
        let exe = self.load(name)?;
        let _guard = self.exec_lock.lock().unwrap();
        let t0 = Instant::now();
        let out = exe.run(inputs)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        drop(_guard);
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_ms += ms;
        s.per_call.push(ms);
        Ok((out, ms))
    }

    /// Snapshot of per-artifact stats (name, calls, mean ms, total ms).
    pub fn stats(&self) -> Vec<(String, u64, f64, f64)> {
        let stats = self.stats.lock().unwrap();
        let mut rows: Vec<_> = stats
            .iter()
            .map(|(k, s)| (k.clone(), s.calls, s.per_call.mean(), s.total_ms))
            .collect();
        rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
        rows
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Shared runtime handle used across coordinator/worker threads.
pub type SharedRuntime = Arc<Runtime>;

pub fn open_shared() -> Result<SharedRuntime> {
    Ok(Arc::new(Runtime::open_default()?))
}

/// [`open_shared`], or `None` with a skip message on stderr when the AOT
/// artifacts / XLA bindings are unavailable.  The single gate every
/// artifact-dependent test goes through, so `cargo test -q` is green on
/// a fresh checkout and the skip policy lives in one place.
pub fn open_shared_or_skip() -> Option<SharedRuntime> {
    match open_shared() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: XLA artifacts unavailable ({e:#}) — run `make artifacts`");
            None
        }
    }
}
