//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! `artifacts/manifest.json` is the only contract between the build-time
//! Python world and the Rust runtime: artifact names, HLO file names and
//! exact input/output signatures, plus the net descriptions (parameter
//! names/shapes, batch, lr/β) the coordinator needs to allocate state.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct ConvLayerSpec {
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub pad: usize,
}

/// Mirror of model.NetSpec, read from the manifest so both languages
/// agree by construction.
#[derive(Debug, Clone)]
pub struct NetSpec {
    pub name: String,
    pub input_hw: usize,
    pub input_c: usize,
    pub batch: usize,
    pub n_classes: usize,
    pub fc_in: usize,
    pub convs: Vec<ConvLayerSpec>,
    pub param_names: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub lr: f32,
    pub beta: f32,
}

impl NetSpec {
    pub fn conv_param_names(&self) -> &[String] {
        &self.param_names[..self.param_names.len() - 2]
    }

    pub fn x_shape(&self) -> Vec<usize> {
        vec![self.batch, self.input_hw, self.input_hw, self.input_c]
    }

    pub fn y_shape(&self) -> Vec<usize> {
        vec![self.batch, self.n_classes]
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes.values().map(|s| s.iter().product::<usize>()).sum()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSig>,
    pub nets: BTreeMap<String, NetSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in v.get("artifacts")?.as_obj()? {
            let mut inputs = Vec::new();
            for inp in entry.get("inputs")?.as_arr()? {
                inputs.push(TensorSig {
                    name: inp.get("name")?.as_str()?.to_string(),
                    shape: inp.get("shape")?.as_usize_vec()?,
                });
            }
            let outputs = entry
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.get("name")?.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    name: name.clone(),
                    file: dir.join(entry.get("file")?.as_str()?),
                    inputs,
                    outputs,
                },
            );
        }
        let mut nets = BTreeMap::new();
        for (name, n) in v.get("nets")?.as_obj()? {
            let mut convs = Vec::new();
            for c in n.get("convs")?.as_arr()? {
                convs.push(ConvLayerSpec {
                    kh: c.get("kh")?.as_usize()?,
                    kw: c.get("kw")?.as_usize()?,
                    cin: c.get("cin")?.as_usize()?,
                    cout: c.get("cout")?.as_usize()?,
                    pad: c.get("pad")?.as_usize()?,
                });
            }
            let param_names = n
                .get("param_names")?
                .as_arr()?
                .iter()
                .map(|p| Ok(p.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            let mut param_shapes = BTreeMap::new();
            for (k, s) in n.get("param_shapes")?.as_obj()? {
                param_shapes.insert(k.clone(), s.as_usize_vec()?);
            }
            nets.insert(
                name.clone(),
                NetSpec {
                    name: name.clone(),
                    input_hw: n.get("input_hw")?.as_usize()?,
                    input_c: n.get("input_c")?.as_usize()?,
                    batch: n.get("batch")?.as_usize()?,
                    n_classes: n.get("n_classes")?.as_usize()?,
                    fc_in: n.get("fc_in")?.as_usize()?,
                    convs,
                    param_names,
                    param_shapes,
                    lr: n.get("lr")?.as_f64()? as f32,
                    beta: n.get("beta")?.as_f64()? as f32,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, nets })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!("artifact {name:?} not in manifest (have: {:?})", self.artifacts.keys())
        })
    }

    pub fn net(&self, name: &str) -> Result<&NetSpec> {
        self.nets
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("net {name:?} not in manifest"))
    }
}

/// Locate the artifacts directory: $SASHIMI_ARTIFACTS or ./artifacts
/// relative to the workspace root (walking up from cwd).
pub fn default_artifacts_dir() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("SASHIMI_ARTIFACTS") {
        return Ok(PathBuf::from(p));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            bail!("artifacts/manifest.json not found — run `make artifacts`");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "nets": {"tiny": {
        "input_hw": 8, "input_c": 1, "batch": 2, "n_classes": 3, "fc_in": 16,
        "convs": [{"kh":5,"kw":5,"cin":1,"cout":4,"pad":2}],
        "param_names": ["conv1_w","conv1_b","fc_w","fc_b"],
        "param_shapes": {"conv1_w":[25,4],"conv1_b":[4],"fc_w":[16,3],"fc_b":[3]},
        "lr": 0.01, "beta": 1.0
      }},
      "artifacts": {"f": {
        "file": "f.hlo.txt",
        "inputs": [{"name":"x","shape":[2,3]}],
        "outputs": [{"name":"y"}]
      }}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let a = m.artifact("f").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].numel(), 6);
        assert_eq!(a.file, Path::new("/tmp/a/f.hlo.txt"));
        let n = m.net("tiny").unwrap();
        assert_eq!(n.conv_param_names(), &["conv1_w", "conv1_b"]);
        assert_eq!(n.x_shape(), vec![2, 8, 8, 1]);
        assert_eq!(n.param_count(), 25 * 4 + 4 + 16 * 3 + 3);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.net("nope").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        if let Ok(dir) = default_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("smoke_matmul"));
            let cifar = m.net("cifar").unwrap();
            assert_eq!(cifar.fc_in, 320);
            assert_eq!(cifar.batch, 50);
        }
    }
}
