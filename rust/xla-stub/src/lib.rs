//! Stub of the `xla` crate's PJRT surface, exactly as `sashimi::runtime`
//! consumes it.
//!
//! The real crate wraps `xla_extension` (PJRT C API) and needs a libxla
//! download at build time, which offline checkouts cannot perform.  This
//! stub keeps the workspace compiling everywhere: every constructor that
//! would reach PJRT fails with [`Error::Unavailable`], so
//! `runtime::open_shared()` returns an error instead of aborting, and the
//! artifact-gated integration tests skip cleanly.
//!
//! To run against real XLA, point the workspace's `xla` dependency at the
//! published bindings (see the comment in the root `Cargo.toml`); no
//! sashimi source changes are required — the call surface is identical.

use std::fmt;

/// Error type mirroring the real crate's: printable, `std::error::Error`,
/// `Send + Sync` so it converts into `anyhow::Error`.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
    Message(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: XLA/PJRT is not available in this build (stub `xla` crate); \
                 link the real bindings to execute artifacts"
            ),
            Error::Message(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types of literals (only F32 is used by sashimi).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Dense host literal (stub: holds nothing; constructors fail).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation built from a parsed module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled + loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: `cpu()` fails, so `Runtime::new` reports a clear
/// "no XLA in this build" error and callers skip/abort gracefully).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_fails_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
    }

    #[test]
    fn error_converts_to_anyhow_style_boxes() {
        fn takes_std_error(_: Box<dyn std::error::Error + Send + Sync>) {}
        takes_std_error(Box::new(Error::Message("m".into())));
    }
}
