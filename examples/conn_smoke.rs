//! Connection-scale smoke driver (ISSUE 8 nightly CI job).
//!
//! Stands up the epoll gateway, parks `--conns` idle JSON-lines
//! connections against it, then drives `--workers` real workers through
//! the crowd until `--tickets` prime tickets complete.  Emits a metrics
//! JSON document (fd/thread/RSS footprint, timings, gateway counters)
//! for the nightly artifact trail, and exits non-zero if the crowd was
//! culled, memory blew up, or threads multiplied.
//!
//! ```text
//! cargo run --release --example conn_smoke -- --conns 5000
//! cargo run --release --example conn_smoke -- --conns 20000 --workers 8 \
//!     --tickets 1024 --json conn-smoke.json
//! ```

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sashimi::coordinator::gateway::{process_rss_kb, process_thread_count, raise_nofile_limit};
use sashimi::coordinator::{Distributor, Framework, Gateway, GatewayConfig};
use sashimi::store::Scheduler as _;
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::transport::tcp::TcpConn;
use sashimi::transport::{Conn, Message};
use sashimi::util::cli::Args;
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};
use sashimi::Result;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let conns = args.usize_or("conns", 5_000)?;
    let workers = args.usize_or("workers", 4)?;
    let tickets = args.usize_or("tickets", 256)?;
    let heartbeat_ms = args.u64_or("heartbeat-ms", 0)?;
    let json_path = args.get("json").map(String::from);
    args.reject_unknown()?;

    let want_fds = conns as u64 * 2 + 512;
    let granted = raise_nofile_limit(want_fds)?;
    anyhow::ensure!(
        granted >= want_fds,
        "RLIMIT_NOFILE caps at {granted}, need {want_fds} for {conns} connections"
    );
    let threads_before = process_thread_count().unwrap_or(0);
    let rss_before = process_rss_kb().unwrap_or(0);

    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (0..tickets).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
    );
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    let gw = Gateway::bind(&dist, GatewayConfig { heartbeat_ms }, Some("127.0.0.1:0"), None)?;
    let addr = gw.tcp_addr().unwrap();

    // Park the idle crowd: connect, Hello, silence.
    let t_crowd = Instant::now();
    let mut crowd: Vec<TcpStream> = Vec::with_capacity(conns);
    for i in 0..conns {
        let mut s = {
            let mut attempt = 0;
            loop {
                match TcpStream::connect(&addr) {
                    Ok(s) => break s,
                    Err(e) => {
                        attempt += 1;
                        anyhow::ensure!(attempt < 50, "connect {i} of {conns} failed: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
        };
        let hello = Message::Hello { client: format!("idle-{i}"), profile: "crowd".into() };
        s.write_all(format!("{}\n", hello.encode()).as_bytes())?;
        crowd.push(s);
    }
    for (i, s) in crowd.iter().enumerate() {
        s.set_read_timeout(Some(Duration::from_secs(30)))?;
        let mut r = BufReader::new(s.try_clone()?);
        let mut line = String::new();
        r.read_line(&mut line)?;
        anyhow::ensure!(
            matches!(Message::decode(line.trim_end())?, Message::Ack),
            "idle-{i} got {line:?} instead of Ack"
        );
    }
    let crowd_s = t_crowd.elapsed().as_secs_f64();
    println!("parked {conns} idle connections in {crowd_s:.2} s");

    // Drive the active workers through the crowd.
    let t_drain = Instant::now();
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for i in 0..workers {
        let addr = addr.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        joins.push(std::thread::spawn(move || {
            let mut w = Worker::new(&format!("active-{i}"), DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(TcpConn::connect(&addr)?) as Box<dyn Conn>), &stop)
        }));
    }
    let results = fw
        .store()
        .wait_results_timeout(task_id, 300_000)
        .ok_or_else(|| anyhow::anyhow!("workers timed out behind the crowd"))?;
    stop.store(true, Ordering::SeqCst);
    let mut completed = 0u64;
    for j in joins {
        completed += j.join().map_err(|_| anyhow::anyhow!("worker panicked"))?.tickets_completed;
    }
    let drain_s = t_drain.elapsed().as_secs_f64();
    println!("{workers} workers drained {} tickets in {drain_s:.2} s", results.len());

    let threads_now = process_thread_count().unwrap_or(0);
    let rss_now = process_rss_kb().unwrap_or(0);
    let open = gw.stats.open.load(Ordering::Relaxed);
    let peak = gw.stats.peak_open.load(Ordering::Relaxed);
    let kills = gw.stats.dead_peer_kills.load(Ordering::Relaxed);
    let proto_errs = gw.stats.protocol_errors.load(Ordering::Relaxed);

    let metrics = Value::obj(vec![
        ("conns", Value::num(conns as f64)),
        ("workers", Value::num(workers as f64)),
        ("tickets", Value::num(tickets as f64)),
        ("heartbeat_ms", Value::num(heartbeat_ms as f64)),
        ("crowd_setup_s", Value::num(crowd_s)),
        ("drain_s", Value::num(drain_s)),
        ("open_at_end", Value::num(open as f64)),
        ("peak_open", Value::num(peak as f64)),
        ("dead_peer_kills", Value::num(kills as f64)),
        ("protocol_errors", Value::num(proto_errs as f64)),
        ("threads_before", Value::num(threads_before as f64)),
        ("threads_after", Value::num(threads_now as f64)),
        ("rss_kb_before", Value::num(rss_before as f64)),
        ("rss_kb_after", Value::num(rss_now as f64)),
        ("client_count", Value::num(dist.client_count() as f64)),
    ]);
    let doc = metrics.to_string();
    if let Some(path) = json_path {
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("metrics written to {path}");
    } else {
        println!("{doc}");
    }

    // The claims the nightly job enforces.
    anyhow::ensure!(results.len() == tickets && completed == tickets as u64, "tickets lost");
    anyhow::ensure!(open as usize >= conns, "idle crowd culled: open={open}");
    anyhow::ensure!(kills == 0 || heartbeat_ms > 0, "killed idle peers with heartbeats off");
    anyhow::ensure!(
        threads_now < threads_before + 64,
        "thread explosion: {threads_before} -> {threads_now}"
    );
    anyhow::ensure!(rss_now < 2 * 1_048_576, "RSS {rss_now} KiB — memory is not bounded");

    drop(crowd);
    gw.shutdown();
    Ok(())
}
