//! Fleet-scale churn soak driver.
//!
//! Runs the deterministic discrete-event simulator in `sashimi::sim`:
//! one real Distributor + WAL store coordinator, thousands of simulated
//! browsers churning on a virtual clock.  Ten simulated minutes of a
//! 10k-browser fleet replay in seconds of wall time, and the whole run
//! is a pure function of the seed.
//!
//! ```text
//! cargo run --release --example churn_soak -- --quick
//! cargo run --release --example churn_soak -- --workers 10000 --seed 1 \
//!     --duration 600000 --json soak-metrics.json
//! cargo run --release --example churn_soak -- --quick --passive --trace
//! cargo run --release --example churn_soak -- --adversarial
//! cargo run --release --example churn_soak -- --workers 10000 \
//!     --replication 3 --quorum 2 --wrong-permille 150 \
//!     --corrupt-permille 100 --collude-permille 50 --json soak-metrics.json
//! ```

use sashimi::sim::{run_soak, SoakConfig};
use sashimi::util::cli::Args;
use sashimi::Result;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let quick = args.flag("quick");
    let adversarial = args.flag("adversarial");
    let base = if adversarial {
        SoakConfig::adversarial_quick()
    } else if quick {
        SoakConfig::quick()
    } else {
        SoakConfig::new(10_000, 1)
    };

    let mut cfg = SoakConfig::new(
        args.usize_or("workers", base.workers)?,
        args.u64_or("seed", base.seed)?,
    );
    cfg.store_cfg = base.store_cfg.clone();
    cfg.adversary_wrong_permille = base.adversary_wrong_permille;
    cfg.duration_ms = args.u64_or("duration", base.duration_ms)?;
    cfg.prime_tickets = args.usize_or("tickets", cfg.prime_tickets)?;
    cfg.prefetch_cap = args.usize_or("prefetch-cap", cfg.prefetch_cap)?;
    cfg.mean_lifetime_ms = args.u64_or("mean-lifetime", cfg.mean_lifetime_ms)?;
    cfg.error_permille = args.u64_or("error-permille", cfg.error_permille)?;
    // Verification layer (§2.8): replicate tickets across distinct
    // clients and complete on matching votes; adversary classes seed
    // the fleet with deterministic liars to soak against.
    cfg.store_cfg.replication = args.usize_or("replication", cfg.store_cfg.replication)? as u32;
    cfg.store_cfg.quorum = args.usize_or("quorum", cfg.store_cfg.quorum)? as u32;
    cfg.adversary_wrong_permille = args.u64_or("wrong-permille", cfg.adversary_wrong_permille)?;
    cfg.adversary_corrupt_permille =
        args.u64_or("corrupt-permille", cfg.adversary_corrupt_permille)?;
    cfg.adversary_collude_permille =
        args.u64_or("collude-permille", cfg.adversary_collude_permille)?;
    if args.flag("passive") {
        // The paper's §2.1.2 baseline: vanished browsers strand their
        // tickets until the redistribution window expires.
        cfg.release_on_disconnect = false;
    }
    let json_path = args.get("json").map(String::from);
    let show_trace = args.flag("trace");
    args.reject_unknown()?;

    let wall = std::time::Instant::now();
    let report = run_soak(&cfg)?;
    let wall_s = wall.elapsed().as_secs_f64();

    if show_trace {
        for line in &report.trace {
            println!("{line}");
        }
        println!();
    }
    print!("{}", report.table);
    println!(
        "  wall time      {:.2} s  ({:.0}x faster than the {:.0} s it simulates)",
        wall_s,
        (report.virtual_ms as f64 / 1000.0) / wall_s.max(1e-9),
        report.virtual_ms as f64 / 1000.0
    );

    if let Some(path) = json_path {
        std::fs::write(&path, format!("{}\n", report.metrics_json))?;
        println!("  metrics        {path}");
    } else {
        println!("{}", report.metrics_json);
    }

    anyhow::ensure!(report.done == report.total, "soak lost tickets");
    anyhow::ensure!(report.ghosts_after_close == 0, "soak leaked ghost clients");
    anyhow::ensure!(
        report.poisoned_completions == 0,
        "verification accepted {} poisoned results",
        report.poisoned_completions
    );
    Ok(())
}
