//! Distributed MNIST nearest-neighbour classification — the paper's
//! §2.2 benchmark workload as a runnable example.
//!
//! 200 query images are classified against 6,000 training images by
//! splitting the work into (query window × training chunk) tickets and
//! distributing them across simulated browser clients.  The kNN distance
//! matrix runs through the `knn_chunk` AOT artifact (Pallas matmul).
//!
//! ```bash
//! cargo run --release --example knn_mnist -- --clients 3 --profile desktop
//! ```

use sashimi::data;
use sashimi::runtime;
use sashimi::tasks::knn::project::{run, KnnRunConfig};
use sashimi::transport::LinkModel;
use sashimi::util::cli::Args;
use sashimi::worker::DeviceProfile;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let clients = args.usize_or("clients", 2)?;
    let profile = match args.str_or("profile", "native").as_str() {
        "desktop" => DeviceProfile::desktop(),
        "tablet" => DeviceProfile::tablet(),
        _ => DeviceProfile::native(),
    };
    args.reject_unknown()?;

    let rt = runtime::open_shared()?;
    println!("generating synthetic MNIST (6,000 train / 200 queries)...");
    let train = data::mnist_train(6_000, 1);
    let queries = data::mnist_test(200, 2);

    let cfg = KnnRunConfig {
        n_queries: 200,
        n_train: 6_000,
        clients,
        profile: profile.clone(),
        link: LinkModel::INTERNET,
        sleep_on_link: false,
        small: false, // 100x2000 artifact -> 2 windows x 3 chunks = 6 tickets
    };
    println!(
        "distributing {} query-window x train-chunk tickets to {clients} x {} clients...",
        (cfg.n_queries / 100) * (cfg.n_train / 2000),
        profile.name
    );
    let result = run(rt, &queries, &train, &cfg)?;

    println!("\nelapsed: {:.2}s  accuracy: {:.1}%", result.elapsed_s, result.accuracy * 100.0);
    for (i, r) in result.reports.iter().enumerate() {
        println!(
            "client{i}: {} tickets, {} dataset fetches, busy {:.0} ms",
            r.tickets_completed, r.data_fetches, r.busy_ms
        );
    }
    if result.redistributions > 0 {
        println!("redistributions: {}", result.redistributions);
    }
    anyhow::ensure!(result.accuracy > 0.8, "kNN accuracy should beat 80% on synthetic MNIST");
    Ok(())
}
