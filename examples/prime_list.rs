//! PrimeListMakerProject — the paper's appendix sample, line for line.
//!
//! Source Code 1 (`prime_list_maker_project.js`):
//! ```js
//! var task = this.createTask(IsPrimeTask);
//! var inputs = [];
//! for (var i = 1; i <= 10000; i++) inputs.push({ candidate: i });
//! task.calculate(inputs);
//! task.block(function(results) { ... });
//! ```
//!
//! Here: the same project through `Framework::create_task` /
//! `TaskHandle::calculate` / `TaskHandle::block`, with four simulated
//! browser nodes pulling tickets from the distributor, then the console
//! the paper's HTTPServer would render.
//!
//! ```bash
//! cargo run --release --example prime_list
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{console, Distributor, Framework};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::transport::{local, Conn, LinkModel};
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};

fn main() -> anyhow::Result<()> {
    // PrimeListMakerProject.run()
    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    let inputs: Vec<Value> =
        (1..=10_000).map(|i| Value::obj(vec![("candidate", Value::num(i as f64))])).collect();
    task.calculate(inputs);

    // The Distributor + four browsers that "accessed the website".
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..4)
        .map(|i| {
            let connector = connector.clone();
            let registry = fw.registry_snapshot();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut w = Worker::new(&format!("browser{i}"), DeviceProfile::native(), registry);
                w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
            })
        })
        .collect();

    // task.block(function(results) { ... })
    let t0 = std::time::Instant::now();
    let results = task.block();
    let elapsed = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);

    let primes: Vec<usize> = results
        .iter()
        .enumerate()
        .filter(|(_, r)| r.get("is_prime").unwrap().as_bool().unwrap())
        .map(|(i, _)| i + 1)
        .collect();
    for p in primes.iter().take(10) {
        println!("{p} is a prime number.");
    }
    println!("... {} primes below 10,000 in {:.2}s across 4 browser nodes", primes.len(), elapsed);
    assert_eq!(primes.len(), 1229); // π(10000)

    // Per-render console is counters-only; the client table is the
    // one-shot end-of-run view.
    println!("\n{}", console::render(&console::snapshot(&dist)));
    print!("{}", console::render_clients(&dist));
    for w in workers {
        let report = w.join().unwrap();
        println!(
            "worker: {:>5} tickets, {} task fetch, {} reloads",
            report.tickets_completed, report.task_fetches, report.reloads
        );
    }
    Ok(())
}
