//! Quickstart: the Sukiyaki engine API in five minutes.
//!
//! Trains the MNIST-shaped CNN through the AOT/XLA engine, evaluates the
//! error rate, round-trips the model through the paper's JSON+base64
//! model file, and shows the ConvNetJS-style baseline on the same init.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use sashimi::data::{self, loader::BatchLoader};
use sashimi::nn::model_file::ModelFile;
use sashimi::nn::{metrics, NativeEngine, ParamSet, TrainEngine, XlaEngine};
use sashimi::runtime;
use sashimi::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    // 1. Open the PJRT runtime over the AOT artifacts (`make artifacts`).
    let rt = runtime::open_shared()?;
    println!("runtime: {} | nets: {:?}", rt.platform(), rt.manifest().nets.keys());
    let spec = rt.net("mnist")?.clone();

    // 2. Synthetic MNIST (no network access in this environment; see
    //    DESIGN.md §2) and a deterministic batch stream.
    let train = data::mnist_train(2_000, 1);
    let test = data::mnist_test(500, 2);
    let mut loader = BatchLoader::new(&train, spec.batch, 3);

    // 3. Sukiyaki engine: one fused train-step artifact per mini-batch.
    let mut rng = SplitMix64::new(7);
    let init = ParamSet::init(&spec, &mut rng);
    let mut engine = XlaEngine::from_params(rt.clone(), "mnist", init.clone())?;
    engine.warm()?; // compile outside the timed loop

    let t0 = std::time::Instant::now();
    let steps = 60;
    for step in 0..steps {
        let (x, y, _) = loader.next_batch();
        let loss = engine.train_batch(&x, &y)?;
        if step % 10 == 0 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }
    let ms_per_step = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
    println!("sukiyaki-xla: {:.1} ms/step = {:.0} batches/min", ms_per_step, 60_000.0 / ms_per_step);

    // 4. Evaluate on held-out data.
    let mut test_loader = BatchLoader::new(&test, spec.batch, 4);
    let mut errs = Vec::new();
    for _ in 0..5 {
        let (x, _, labels) = test_loader.next_batch();
        errs.push(metrics::error_rate(&engine.forward(&x)?, &labels));
    }
    let err = errs.iter().sum::<f32>() / errs.len() as f32;
    println!("held-out error rate after {steps} steps: {:.1}% (chance 90%)", err * 100.0);

    // 5. Model file round-trip (§3.1: JSON + base64, no rounding error).
    let path = std::env::temp_dir().join("sukiyaki_mnist.json");
    ModelFile { net: "mnist".into(), step: steps as u64, params: engine.params().clone(), accums: None }
        .save(&path)?;
    let loaded = ModelFile::load(&path, &spec.param_names)?;
    assert_eq!(loaded.params.get("fc_w")?.data(), engine.params().get("fc_w")?.data());
    println!("model file round-trip OK: {}", path.display());

    // 6. The ConvNetJS-style baseline from the identical init.
    let mut baseline = NativeEngine::from_params(&spec, init);
    let mut loader2 = BatchLoader::new(&train, spec.batch, 3);
    let t1 = std::time::Instant::now();
    for _ in 0..10 {
        let (x, y, _) = loader2.next_batch();
        baseline.train_batch(&x, &y)?;
    }
    let base_ms = t1.elapsed().as_secs_f64() * 1e3 / 10.0;
    println!(
        "convnetjs-naive: {:.1} ms/step — sukiyaki speedup {:.1}x (Table 4's comparison)",
        base_ms,
        base_ms / ms_per_step
    );
    Ok(())
}
