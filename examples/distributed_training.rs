//! End-to-end driver: distributed training of the paper's Fig 2 CIFAR
//! CNN with the §4 hybrid algorithm, on a live Sashimi cluster.
//!
//! This is the repository's full-stack validation (EXPERIMENTS.md §E2E):
//! L3 coordination (tickets, distributor, browser-loop workers, dataset
//! caching) driving L2/L1 AOT artifacts (JAX graph + Pallas kernels) for
//! several hundred FC update steps and dozens of distributed conv
//! rounds, logging the loss curve and finishing with a held-out
//! error-rate evaluation — the loss must actually fall through the
//! whole distributed pipeline, not just in a unit test.
//!
//! ```bash
//! cargo run --release --example distributed_training -- \
//!     --net cifar --clients 2 --rounds 30
//! ```

use sashimi::data::{self, loader::BatchLoader};
use sashimi::dist::{self, Cluster, ClusterConfig};
use sashimi::nn::{metrics, TrainEngine, XlaEngine};
use sashimi::runtime;
use sashimi::util::cli::Args;
use sashimi::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let net = args.str_or("net", "cifar");
    let clients = args.usize_or("clients", 2)?;
    let rounds = args.u64_or("rounds", 30)?;
    let out = args.str_or("curve-out", "");
    args.reject_unknown()?;

    let rt = runtime::open_shared()?;
    let spec = rt.net(&net)?.clone();
    println!(
        "== distributed deep learning: {} ({} params, batch {}) on {clients} clients ==",
        net,
        spec.param_count(),
        spec.batch
    );
    let dataset = if net == "cifar" {
        data::cifar_train(2_000, 31)
    } else {
        data::mnist_train(2_000, 31)
    };

    let mut cfg = ClusterConfig::quick_test(&net, clients);
    cfg.n_shards = clients.max(2) * 2; // more shards than clients: real queueing
    let cluster = Cluster::start(cfg, rt.clone(), &dataset)?;
    let hycfg = dist::hybrid::HybridConfig {
        rounds,
        seed: 42,
        max_replay_per_round: 16,
        poll_ms: 2,
        ..Default::default()
    };
    let result = dist::hybrid::train(&cluster, &hycfg)?;
    let reports = cluster.shutdown();

    println!("\nloss curve (round, wall ms, mean loss):");
    print!("{}", result.loss_curve.dump("hybrid-cifar"));
    if !out.is_empty() {
        std::fs::write(&out, result.loss_curve.dump("hybrid-cifar"))?;
        println!("curve written to {out}");
    }
    println!(
        "\nconv: {} batches ({:.2}/s) | fc: {} steps ({:.2}/s, {} replay) | {:.1} MB moved",
        result.conv_batches,
        result.stats.conv_batches_per_s,
        result.fc_steps,
        result.stats.fc_steps_per_s,
        result.replay_steps,
        (result.stats.bytes.0 + result.stats.bytes.1) as f64 / 1e6,
    );
    for (i, r) in reports.iter().enumerate() {
        println!("client{i}: {} tickets, {} data fetches", r.tickets_completed, r.data_fetches);
    }

    let head = result.loss_curve.head_mean(3);
    let tail = result.loss_curve.tail_mean(3);
    println!("\nloss: first rounds {head:.4} -> last rounds {tail:.4}");
    anyhow::ensure!(tail < head, "distributed training failed to reduce the loss");

    // Held-out evaluation: train a standalone reference for the same
    // number of gradient steps and compare error rates, closing the loop
    // between the distributed pipeline and the standalone engine.
    let eval_data =
        if net == "cifar" { data::cifar_test(500, 32) } else { data::mnist_test(500, 32) };
    let mut rng = SplitMix64::new(42);
    let mut standalone = XlaEngine::new(rt, &net, &mut rng)?;
    standalone.warm()?;
    let mut loader = BatchLoader::new(&dataset, spec.batch, 5);
    for _ in 0..result.conv_batches {
        let (x, y, _) = loader.next_batch();
        standalone.train_batch(&x, &y)?;
    }
    let mut eval_loader = BatchLoader::new(&eval_data, spec.batch, 6);
    let mut errs = Vec::new();
    for _ in 0..5 {
        let (x, _, labels) = eval_loader.next_batch();
        errs.push(metrics::error_rate(&standalone.forward(&x)?, &labels) as f64);
    }
    let err = errs.iter().sum::<f64>() / errs.len() as f64;
    println!(
        "standalone reference after {} steps: held-out error {:.1}% (chance 90%)",
        result.conv_batches,
        err * 100.0
    );
    Ok(())
}
