"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

These are the ground truth the pytest suite compares the Pallas kernels
against, and they double as the "pure-jnp roofline" engine for the §Perf
L1 comparison (aot.py can lower the whole model through either path).

The paper's analogue: Sukiyaki's layer implementations, which were checked
against ConvNetJS outputs.  Here the oracle is jnp/XLA itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain f32 matmul: [M,K] @ [K,N] -> [M,N]."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def matmul_bias(a: jax.Array, b: jax.Array, bias: jax.Array) -> jax.Array:
    """Matmul with broadcast bias add along N: [M,K]@[K,N] + [N]."""
    return matmul(a, b) + bias[None, :]


def im2col(x: jax.Array, kh: int, kw: int, pad: int) -> jax.Array:
    """Extract kh*kw patches (stride 1, symmetric zero pad) from NHWC input.

    Returns [B, H_out, W_out, kh*kw*C] with the (dy, dx, c) axis ordered
    row-major — the same layout the Rust side stores conv weights in
    ([kh*kw*cin, cout]), so conv == matmul(im2col(x), w).
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    h_out = h + 2 * pad - kh + 1
    w_out = w + 2 * pad - kw + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy : dy + h_out, dx : dx + w_out, :])
    # [B, Ho, Wo, kh*kw, C] -> [B, Ho, Wo, kh*kw*C]
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(b, h_out, w_out, kh * kw * c)


def conv2d(x: jax.Array, w: jax.Array, bias: jax.Array, kh: int, kw: int, pad: int) -> jax.Array:
    """Direct convolution oracle, NHWC, stride 1.

    `w` is in im2col layout [kh*kw*cin, cout]; `bias` is [cout].
    """
    b, h, ww, c = x.shape
    cout = w.shape[1]
    patches = im2col(x, kh, kw, pad)
    h_out, w_out = patches.shape[1], patches.shape[2]
    flat = patches.reshape(b * h_out * w_out, kh * kw * c)
    out = matmul(flat, w) + bias[None, :]
    return out.reshape(b, h_out, w_out, cout)


def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 max pooling, stride 2, NHWC. H and W must be even."""
    b, h, w, c = x.shape
    xr = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return xr.max(axis=(2, 4))


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def softmax(logits: jax.Array) -> jax.Array:
    z = logits - logits.max(axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def softmax_xent(logits: jax.Array, y_onehot: jax.Array) -> jax.Array:
    """Mean cross-entropy over the batch (the paper's training loss)."""
    z = logits - logits.max(axis=-1, keepdims=True)
    logp = z - jnp.log(jnp.exp(z).sum(axis=-1, keepdims=True))
    return -(y_onehot * logp).sum(axis=-1).mean()


def adagrad_update(
    theta: jax.Array, accum: jax.Array, grad: jax.Array, lr: float, beta: float
) -> tuple[jax.Array, jax.Array]:
    """The paper's modified AdaGrad (§3.1):

        G_t   = G_{t-1} + g_t^2
        θ_t   = θ_{t-1} - α / sqrt(β + G_t) * g_t

    β stabilises the early steps where Σg² is minuscule.
    """
    new_accum = accum + grad * grad
    new_theta = theta - lr * grad / jnp.sqrt(beta + new_accum)
    return new_theta, new_accum
