"""L1 Pallas kernel: 2x2/stride-2 max pooling (NHWC), with custom VJP.

Sukiyaki's max-pooling layer.  The kernel processes one batch sample per
grid step; a 32x32x20 f32 sample is 80 KiB — the whole activation block
sits in VMEM and the reduction is a register-level max over the 2x2
window axes (no HBM round-trips inside a sample).

The backward pass routes the cotangent to the argmax position.  Like
ConvNetJS (which remembers the winning switch), we recompute the winner
mask from the saved input; ties (measure-zero for conv outputs) split the
gradient equally, which keeps the VJP a true linear transpose.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref):
    x = x_ref[...]  # [nb, H, W, C]
    nb, h, w, c = x.shape
    xr = x.reshape(nb, h // 2, 2, w // 2, 2, c)
    o_ref[...] = xr.max(axis=(2, 4))


# Samples per grid step.  One 32x32x20 f32 sample is 80 KiB, so a whole
# 50-batch block is 4 MiB — within VMEM on TPU and one interpreter step
# on CPU (each grid step costs ~ms under interpret=True; see the §Perf
# log).  Shrink via SASHIMI_POOL_BLOCK for tighter VMEM co-residency.
POOL_BLOCK = int(__import__("os").environ.get("SASHIMI_POOL_BLOCK", 64))


@jax.jit
def _maxpool2_impl(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"maxpool2 needs even H,W, got {x.shape}"
    nb = min(b, POOL_BLOCK)
    grid = -(-b // nb)
    padded = grid * nb
    xp = jnp.pad(x.astype(jnp.float32), ((0, padded - b), (0, 0), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _pool_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((nb, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((nb, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, h // 2, w // 2, c), jnp.float32),
        interpret=True,
    )(xp)
    return out[:b]


@jax.custom_vjp
def maxpool2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max pool over NHWC input with even H, W."""
    return _maxpool2_impl(x)


def _maxpool2_fwd(x):
    out = _maxpool2_impl(x)
    return out, (x, out)


def _upsample2(y: jax.Array) -> jax.Array:
    """Nearest-neighbour 2x upsample of NHWC (inverse-shape of maxpool2)."""
    return jnp.repeat(jnp.repeat(y, 2, axis=1), 2, axis=2)


def _maxpool2_bwd(res, g):
    x, out = res
    winners = (x == _upsample2(out)).astype(jnp.float32)
    # Split gradient across ties so the transpose stays linear.
    counts = _maxpool2_sum(winners)
    gx = winners * _upsample2(g / jnp.maximum(counts, 1.0))
    return (gx,)


@jax.jit
def _maxpool2_sum(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).sum(axis=(2, 4))


maxpool2.defvjp(_maxpool2_fwd, _maxpool2_bwd)
