"""L1 Pallas kernel: element-wise AdaGrad-β parameter update.

The paper modifies AdaGrad with a constant β under the square root
(§3.1) because Σg² is minuscule early in training and the vanilla rule
diverges.  This kernel is the per-element WebCL update kernel re-shaped
for the VPU: parameters are flattened to 1-D and processed in 1-D VMEM
blocks; each block does two multiplies, an add, a rsqrt and an fma —
purely element-wise, so any block size that divides into VMEM works.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Element-wise update: any block size is VMEM-legal; big blocks mean one
# interpreter step per tensor on CPU (the dominant cost under
# interpret=True — see EXPERIMENTS.md §Perf).  4M floats = 16 MiB.
BLOCK = int(__import__("os").environ.get("SASHIMI_ADAGRAD_BLOCK", 4 * 1024 * 1024))


def _adagrad_kernel(lr: float, beta: float, theta_ref, accum_ref, grad_ref, new_theta_ref, new_accum_ref):
    g = grad_ref[...]
    acc = accum_ref[...] + g * g
    new_accum_ref[...] = acc
    new_theta_ref[...] = theta_ref[...] - lr * g * jax.lax.rsqrt(beta + acc)


@functools.partial(jax.jit, static_argnames=("lr", "beta"))
def adagrad_update(
    theta: jax.Array, accum: jax.Array, grad: jax.Array, lr: float, beta: float
) -> tuple[jax.Array, jax.Array]:
    """Apply one AdaGrad-β step to a parameter tensor of any shape.

    Returns (theta', accum').  lr/β are compile-time constants — they are
    baked into the artifact, mirroring Sukiyaki's per-run configuration.
    """
    shape = theta.shape
    n = theta.size
    blk = min(BLOCK, n)
    gridn = -(-n // blk)
    padded = gridn * blk

    def flat(x):
        f = x.astype(jnp.float32).reshape(-1)
        return jnp.pad(f, (0, padded - n)) if padded != n else f

    new_theta, new_accum = pl.pallas_call(
        functools.partial(_adagrad_kernel, lr, beta),
        grid=(gridn,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((padded,), jnp.float32)] * 2,
        interpret=True,
    )(flat(theta), flat(accum), flat(grad))
    return new_theta[:n].reshape(shape), new_accum[:n].reshape(shape)
