"""L1 Pallas kernel: tiled f32 matmul — the Sushi hot spot.

The paper's Sukiyaki runs every FC layer and (via im2col) every conv layer
through one generic WebCL matmul in the Sushi library.  This file is the
TPU-shaped equivalent: a Pallas kernel with a (M/bm, N/bn, K/bk) grid,
VMEM-resident blocks, and an MXU-shaped `jnp.dot` per block.  The K axis
is the innermost grid dimension and accumulates into the output block,
which stays resident in VMEM across the K loop (revisiting grid dims keeps
the block mapped — the Pallas equivalent of the WebCL local-memory
accumulator).

Lowered with interpret=True everywhere (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §Hardware-Adaptation for the VMEM/MXU
utilisation estimate on real hardware.

`matmul` is wrapped in jax.custom_vjp so jax.grad flows through the model:
the backward pass is itself two Pallas matmuls (dA = g @ B^T, dB = A^T @ g)
— the gradient path exercises the same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block selection is budget-driven: pick the largest tiles whose
# (a, b, out) triple fits the scratchpad budget, shrinking M first (the
# streaming axis), then K, then N.
#
# * On real TPU the budget is VMEM: SASHIMI_BLOCK_BUDGET=16MiB yields the
#   classic 128x128 tiling for large matmuls (DESIGN.md §Hardware-
#   Adaptation analyses that configuration).
# * Under interpret=True on CPU (this image), every grid step costs ~ms
#   of interpreter dispatch, so the budget defaults to 256 MiB — all of
#   this model zoo's matmuls then run as a single block and the kernel
#   is one fused dot, which is the correct "tile" for a cache-coherent
#   CPU.  The §Perf log in EXPERIMENTS.md records the 56x train-step
#   delta between the two settings.
#
# The multi-block path stays correctness-tested via explicit block
# arguments in python/tests/test_kernels.py regardless of the budget.
DEFAULT_BUDGET_BYTES = int(
    __import__("os").environ.get("SASHIMI_BLOCK_BUDGET", 256 * 1024 * 1024)
)


def _round8(x: int) -> int:
    return max(8, ((x + 7) // 8) * 8)


def _pick_blocks(m: int, k: int, n: int, budget: int = DEFAULT_BUDGET_BYTES) -> tuple[int, int, int]:
    """(bm, bk, bn) with (bm*bk + bk*bn + bm*bn)*4 <= budget."""

    def fits(bm, bk, bn):
        return 4 * (bm * bk + bk * bn + bm * bn) <= budget

    # Single-block fast path: when the whole matmul fits the budget, use
    # the exact dims — zero padding, zero operand copies (§Perf: padding
    # a 51200x75 conv-im2col operand to x80 cost ~2x on the train step).
    if fits(m, k, n):
        return m, k, n

    bm, bk, bn = _round8(m), _round8(k), _round8(n)
    # Shrink M (halving, floor 128), then K, then N until the triple fits.
    while not fits(bm, bk, bn) and bm > 128:
        bm = _round8(bm // 2)
    while not fits(bm, bk, bn) and bk > 128:
        bk = _round8(bk // 2)
    while not fits(bm, bk, bn) and bn > 128:
        bn = _round8(bn // 2)
    return bm, bk, bn


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def _matmul_impl(
    a: jax.Array,
    b: jax.Array,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    auto_m, auto_k, auto_n = _pick_blocks(m, k, n)
    bm = block_m or auto_m
    bn = block_n or auto_n
    bk = block_k or auto_k
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    ap = _pad_to(a.astype(jnp.float32), gm * bm, gk * bk)
    bp = _pad_to(b.astype(jnp.float32), gk * bk, gn * bn)
    out = pl.pallas_call(
        _mm_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Differentiable tiled Pallas matmul: [M,K] @ [K,N] -> [M,N] (f32)."""
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # Both cotangents run through the same Pallas kernel.
    da = _matmul_impl(g, b.T)
    db = _matmul_impl(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_bias(a: jax.Array, b: jax.Array, bias: jax.Array) -> jax.Array:
    """Matmul + broadcast bias: the FC layer primitive."""
    return matmul(a, b) + bias[None, :]
