"""L1 composite: convolution = im2col + Pallas matmul (the Sushi trick).

Sukiyaki implements its conv layers exactly this way on WebCL: patches are
unfolded and the whole layer becomes one big matmul against the weight
matrix in [kh*kw*cin, cout] layout.  We keep the identical layout on the
Rust/model-file side so parameters round-trip without permutation.

im2col itself is differentiable jnp slicing (its transpose is the
col2im scatter, derived automatically), so jax.grad through `conv2d`
yields a backward pass whose FLOPs all land in the Pallas matmul kernel:
    dW = patches^T @ g        (Pallas matmul)
    dpatches = g @ W^T        (Pallas matmul)  -> col2im -> dx
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul as mm


def im2col(x: jax.Array, kh: int, kw: int, pad: int) -> jax.Array:
    """[B,H,W,C] -> [B,Ho,Wo,kh*kw*C], stride 1, symmetric zero padding.

    Patch channel order is (dy, dx, c) row-major — matches ref.im2col and
    the Rust-side weight layout.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    h_out = h + 2 * pad - kh + 1
    w_out = w + 2 * pad - kw + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy : dy + h_out, dx : dx + w_out, :])
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(b, h_out, w_out, kh * kw * c)


def conv2d(x: jax.Array, w: jax.Array, bias: jax.Array, kh: int, kw: int, pad: int) -> jax.Array:
    """NHWC stride-1 convolution through the Pallas matmul kernel.

    w: [kh*kw*cin, cout] (im2col layout), bias: [cout].
    """
    b = x.shape[0]
    patches = im2col(x, kh, kw, pad)
    h_out, w_out, pk = patches.shape[1], patches.shape[2], patches.shape[3]
    flat = patches.reshape(b * h_out * w_out, pk)
    out = mm.matmul_bias(flat, w, bias)
    return out.reshape(b, h_out, w_out, w.shape[1])
