"""Build-time compile path: L1 Pallas kernels + L2 JAX model + AOT lowering.

Never imported at runtime — `make artifacts` runs once, the Rust binary is
self-contained afterwards.
"""
