"""SplitMix64-based deterministic float streams, bit-identical to the Rust
`util::rng` implementation.

Used to generate golden artifact inputs: aot.py records only (seed, shape,
checksum) and the Rust test suite regenerates the same inputs locally, so
goldens stay tiny even for 150k-element batches.
"""

from __future__ import annotations

import numpy as np

MASK = (1 << 64) - 1


def splitmix64(state: int) -> tuple[int, int]:
    """One SplitMix64 step: returns (new_state, output)."""
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    z = z ^ (z >> 31)
    return state, z


def uniform_f32(seed: int, n: int, lo: float = -1.0, hi: float = 1.0) -> np.ndarray:
    """n f32 values in [lo, hi) from the top 24 bits of each output."""
    out = np.empty(n, dtype=np.float32)
    state = seed & MASK
    scale = np.float32(hi - lo)
    for i in range(n):
        state, z = splitmix64(state)
        u = np.float32((z >> 40) * (1.0 / (1 << 24)))  # [0,1) with 24-bit mantissa
        out[i] = np.float32(lo) + u * scale
    return out


def uniform_f32_array(seed: int, shape: tuple[int, ...], lo: float = -1.0, hi: float = 1.0) -> np.ndarray:
    n = int(np.prod(shape))
    return uniform_f32(seed, n, lo, hi).reshape(shape)


def checksum(x: np.ndarray) -> dict:
    """Compact numeric fingerprint compared (to tolerance) by Rust tests."""
    f = np.asarray(x, dtype=np.float64).reshape(-1)
    return {
        "sum": float(f.sum()),
        "abs_sum": float(np.abs(f).sum()),
        "first": [float(v) for v in f[: min(8, f.size)]],
        "len": int(f.size),
    }
