"""AOT lowering: every L2 graph -> artifacts/<name>.hlo.txt + manifest.json.

Run via `make artifacts` (a no-op when inputs are unchanged).  The Rust
runtime (`rust/src/runtime`) loads the manifest, compiles each HLO text
module on the PJRT CPU client once, and executes from the request path.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published `xla` crate binds) rejects; the text parser reassigns ids.

Besides the HLO, this writes `artifacts/golden.json`: for every artifact,
a SplitMix64 seed for each input plus checksums of every output computed
here with the same jitted function.  The Rust test-suite regenerates the
inputs bit-identically (util::rng) and compares — cross-language numeric
validation without shipping megabytes of tensors.

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--only NAME] [--list]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, prand
from .model import CIFAR, MNIST, NETS, NetSpec

F32 = jnp.float32


def spec(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def _param_specs(net: NetSpec) -> list[jax.ShapeDtypeStruct]:
    shapes = net.param_shapes()
    return [spec(shapes[n]) for n in net.param_names()]


def _named(net: NetSpec, suffix: str = "") -> list[str]:
    return [n + suffix for n in net.param_names()]


class Artifact:
    """One lowerable graph: flat f32 inputs -> tuple of f32 outputs."""

    def __init__(self, name, fn, input_names, input_specs, output_names):
        assert len(input_names) == len(input_specs)
        self.name = name
        self.fn = fn
        self.input_names = input_names
        self.input_specs = input_specs
        self.output_names = output_names

    def lower_hlo_text(self) -> str:
        lowered = jax.jit(self.fn).lower(*self.input_specs)
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        return comp.as_hlo_text()

    def golden(self, seed_base: int) -> dict:
        """Seeded inputs -> output checksums (inputs regenerable in Rust)."""
        inputs, seeds = [], []
        for i, s in enumerate(self.input_specs):
            seed = seed_base + i
            arr = prand.uniform_f32_array(seed, s.shape)
            # One-hot label inputs must be valid distributions for the loss
            # to be meaningful, but checksum validation only needs numeric
            # agreement, so plain uniform values are fine and simpler.
            inputs.append(jnp.asarray(arr))
            seeds.append(seed)
        outs = jax.jit(self.fn)(*inputs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        return {
            "input_seeds": seeds,
            "outputs": {
                name: prand.checksum(np.asarray(o))
                for name, o in zip(self.output_names, outs)
            },
        }

    def manifest_entry(self, filename: str) -> dict:
        return {
            "file": filename,
            "inputs": [
                {"name": n, "shape": list(s.shape)}
                for n, s in zip(self.input_names, self.input_specs)
            ],
            "outputs": [{"name": n} for n in self.output_names],
        }


def build_artifacts() -> list[Artifact]:
    arts: list[Artifact] = []

    # --- tiny smoke graph for runtime unit tests --------------------------
    arts.append(
        Artifact(
            "smoke_matmul",
            model.smoke_matmul,
            ["a", "b"],
            [spec((8, 16)), spec((16, 4))],
            ["out"],
        )
    )

    # --- per-net graphs ----------------------------------------------------
    for net in (CIFAR, MNIST):
        pn = net.param_names()
        np_ = len(pn)
        nconv = len(net.conv_param_names())
        x_s, y_s = spec(net.x_shape), spec((net.batch, net.n_classes))
        feat_s = spec((net.batch, net.fc_in))
        psets = _param_specs(net)
        conv_psets = psets[:nconv]

        def mk_train(net=net, np_=np_):
            def f(*args):
                params, accums = list(args[:np_]), list(args[np_ : 2 * np_])
                x, y = args[2 * np_], args[2 * np_ + 1]
                new_p, new_a, loss = model.train_step(net, params, accums, x, y)
                return (*new_p, *new_a, loss)

            return f

        arts.append(
            Artifact(
                f"{net.name}_train_step",
                mk_train(),
                pn + [n + "_acc" for n in pn] + ["x", "y"],
                psets + psets + [x_s, y_s],
                [n + "_new" for n in pn] + [n + "_acc_new" for n in pn] + ["loss"],
            )
        )

        def mk_forward(net=net, np_=np_):
            def f(*args):
                return (model.forward(net, list(args[:np_]), args[np_]),)

            return f

        arts.append(
            Artifact(
                f"{net.name}_forward",
                mk_forward(),
                pn + ["x"],
                psets + [x_s],
                ["probs"],
            )
        )

        def mk_grad(net=net, np_=np_):
            def f(*args):
                grads, loss = model.grad_all(net, list(args[:np_]), args[np_], args[np_ + 1])
                return (*grads, loss)

            return f

        arts.append(
            Artifact(
                f"{net.name}_grad",
                mk_grad(),
                pn + ["x", "y"],
                psets + [x_s, y_s],
                [n + "_grad" for n in pn] + ["loss"],
            )
        )

        def mk_conv_fwd(net=net, nconv=nconv):
            def f(*args):
                return (model.conv_forward(net, list(args[:nconv]), args[nconv]),)

            return f

        arts.append(
            Artifact(
                f"{net.name}_conv_fwd",
                mk_conv_fwd(),
                net.conv_param_names() + ["x"],
                conv_psets + [x_s],
                ["feat"],
            )
        )

        def mk_conv_grad(net=net, nconv=nconv):
            def f(*args):
                grads = model.conv_grad(net, list(args[:nconv]), args[nconv], args[nconv + 1])
                return tuple(grads)

            return f

        arts.append(
            Artifact(
                f"{net.name}_conv_grad",
                mk_conv_grad(),
                net.conv_param_names() + ["x", "dfeat"],
                conv_psets + [x_s, feat_s],
                [n + "_grad" for n in net.conv_param_names()],
            )
        )

        def mk_fc_step(net=net):
            def f(fc_w, fc_b, acc_w, acc_b, feat, y):
                return model.fc_step(net, fc_w, fc_b, acc_w, acc_b, feat, y)

            return f

        shapes = net.param_shapes()
        arts.append(
            Artifact(
                f"{net.name}_fc_step",
                mk_fc_step(),
                ["fc_w", "fc_b", "fc_w_acc", "fc_b_acc", "feat", "y"],
                [spec(shapes["fc_w"]), spec(shapes["fc_b"]), spec(shapes["fc_w"]), spec(shapes["fc_b"]), feat_s, y_s],
                ["fc_w_new", "fc_b_new", "fc_w_acc_new", "fc_b_acc_new", "dfeat", "loss"],
            )
        )

    # --- pure-jnp oracle variant of the CIFAR train step (perf baseline) ---
    def cifar_train_jnp(*args):
        np_ = len(CIFAR.param_names())
        params, accums = list(args[:np_]), list(args[np_ : 2 * np_])
        x, y = args[2 * np_], args[2 * np_ + 1]
        new_p, new_a, loss = model.train_step(CIFAR, params, accums, x, y, oracle=True)
        return (*new_p, *new_a, loss)

    pn = CIFAR.param_names()
    psets = _param_specs(CIFAR)
    arts.append(
        Artifact(
            "cifar_train_step_jnp",
            cifar_train_jnp,
            pn + [n + "_acc" for n in pn] + ["x", "y"],
            psets + psets + [spec(CIFAR.x_shape), spec((CIFAR.batch, CIFAR.n_classes))],
            [n + "_new" for n in pn] + [n + "_acc_new" for n in pn] + ["loss"],
        )
    )

    # --- kNN chunk (Table 2) ------------------------------------------------
    for qn, cn, tag in ((100, 2000, ""), (20, 200, "_small")):
        def mk_knn(qn=qn, cn=cn):
            def f(q, t):
                return model.knn_chunk(q, t)

            return f

        arts.append(
            Artifact(
                f"knn_chunk{tag}",
                mk_knn(),
                ["q", "t"],
                [spec((qn, 784)), spec((cn, 784))],
                ["min_dist2", "argmin"],
            )
        )

    # --- standalone AdaGrad-β update (server-side aggregated apply) --------
    def adagrad_fn(theta, accum, grad):
        from .kernels import adagrad as k

        return k.adagrad_update(theta, accum, grad, model.LR, model.BETA)

    arts.append(
        Artifact(
            "adagrad_update",
            adagrad_fn,
            ["theta", "accum", "grad"],
            [spec((4096,))] * 3,
            ["theta_new", "accum_new"],
        )
    )

    return arts


def _nets_manifest() -> dict:
    out = {}
    for net in NETS.values():
        out[net.name] = {
            "input_hw": net.input_hw,
            "input_c": net.input_c,
            "batch": net.batch,
            "n_classes": net.n_classes,
            "fc_in": net.fc_in,
            "convs": [
                {"kh": c.kh, "kw": c.kw, "cin": c.cin, "cout": c.cout, "pad": c.pad}
                for c in net.convs
            ],
            "param_names": net.param_names(),
            "param_shapes": {k: list(v) for k, v in net.param_shapes().items()},
            "lr": model.LR,
            "beta": model.BETA,
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower just one artifact")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()

    arts = build_artifacts()
    if args.list:
        for a in arts:
            print(a.name)
        return

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "nets": _nets_manifest(), "artifacts": {}}
    golden: dict = {}
    man_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(man_path):
        with open(man_path) as f:
            manifest["artifacts"] = json.load(f).get("artifacts", {})
        gpath = os.path.join(out_dir, "golden.json")
        if os.path.exists(gpath):
            with open(gpath) as f:
                golden = json.load(f)

    for a in arts:
        if args.only and a.name != args.only:
            continue
        t0 = time.time()
        filename = f"{a.name}.hlo.txt"
        text = a.lower_hlo_text()
        with open(os.path.join(out_dir, filename), "w") as f:
            f.write(text)
        manifest["artifacts"][a.name] = a.manifest_entry(filename)
        if not args.skip_golden:
            seed_base = int.from_bytes(hashlib.sha256(a.name.encode()).digest()[:4], "big")
            golden[a.name] = a.golden(seed_base)
        print(f"lowered {a.name}: {len(text)} chars in {time.time() - t0:.1f}s", flush=True)

    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    print(f"manifest: {man_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
