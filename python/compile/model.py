"""L2: the Sukiyaki model zoo as JAX functions over the L1 Pallas kernels.

Everything here is traced once by aot.py and shipped to the Rust runtime
as HLO text; Python never touches the request path.

Parameter convention (shared with the Rust side, see rust/src/nn):
  * conv weights live in im2col layout [kh*kw*cin, cout], biases [cout];
  * parameters are ordered  conv1_w, conv1_b, ..., fc_w, fc_b;
  * the AdaGrad accumulator set has identical names/shapes/order;
  * every tensor is f32 (labels enter as one-hot f32, argmins leave as
    f32 holding small exact integers).

Nets:
  * `cifar` — the paper's Fig 2 benchmark CNN: 32x32x3 input, three
    5x5 conv(+ReLU+2x2 maxpool) blocks with 16/20/20 maps, then a
    320->10 FC + softmax.  Batch 50 (the paper's mini-batch).
  * `mnist` — a smaller 28x28x1 net (conv5x5x8 + pool + FC 1568->10)
    used by the quickstart and the kNN example's sanity classifier.
The distributed-deep-learning benchmark (the paper's Fig 4 net) reuses
the `cifar` topology — the paper does not give Fig 4's layer table, so we
keep Fig 2's, documented in DESIGN.md §5.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import adagrad as kadagrad
from .kernels import conv as kconv
from .kernels import matmul as kmm
from .kernels import pool as kpool
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    kh: int
    kw: int
    cin: int
    cout: int
    pad: int

    @property
    def w_shape(self) -> tuple[int, int]:
        return (self.kh * self.kw * self.cin, self.cout)


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """A conv-stack + single-FC classifier, i.e. the paper's model family."""

    name: str
    input_hw: int
    input_c: int
    convs: tuple[ConvLayer, ...]
    fc_in: int
    n_classes: int
    batch: int

    @property
    def x_shape(self) -> tuple[int, int, int, int]:
        return (self.batch, self.input_hw, self.input_hw, self.input_c)

    def param_names(self) -> list[str]:
        names = []
        for i in range(len(self.convs)):
            names += [f"conv{i + 1}_w", f"conv{i + 1}_b"]
        names += ["fc_w", "fc_b"]
        return names

    def conv_param_names(self) -> list[str]:
        return self.param_names()[:-2]

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        shapes: dict[str, tuple[int, ...]] = {}
        for i, c in enumerate(self.convs):
            shapes[f"conv{i + 1}_w"] = c.w_shape
            shapes[f"conv{i + 1}_b"] = (c.cout,)
        shapes["fc_w"] = (self.fc_in, self.n_classes)
        shapes["fc_b"] = (self.n_classes,)
        return shapes


CIFAR = NetSpec(
    name="cifar",
    input_hw=32,
    input_c=3,
    convs=(
        ConvLayer(5, 5, 3, 16, 2),
        ConvLayer(5, 5, 16, 20, 2),
        ConvLayer(5, 5, 20, 20, 2),
    ),
    fc_in=4 * 4 * 20,  # 320, as in the paper
    n_classes=10,
    batch=50,
)

MNIST = NetSpec(
    name="mnist",
    input_hw=28,
    input_c=1,
    convs=(ConvLayer(5, 5, 1, 8, 2),),
    fc_in=14 * 14 * 8,  # 1568
    n_classes=10,
    batch=50,
)

NETS = {"cifar": CIFAR, "mnist": MNIST}

LR = 0.01
BETA = 1.0


# ---------------------------------------------------------------------------
# Forward / loss (Pallas path and pure-jnp oracle path)
# ---------------------------------------------------------------------------


def conv_forward(spec: NetSpec, conv_params: list[jax.Array], x: jax.Array, *, oracle: bool = False) -> jax.Array:
    """The conv stack: (conv -> relu -> maxpool2)* then flatten to [B, fc_in].

    This is exactly the piece the paper's hybrid algorithm runs on the
    browser clients.
    """
    c2d = ref.conv2d if oracle else kconv.conv2d
    pool = ref.maxpool2 if oracle else kpool.maxpool2
    h = x
    for i, layer in enumerate(spec.convs):
        w, b = conv_params[2 * i], conv_params[2 * i + 1]
        h = c2d(h, w, b, layer.kh, layer.kw, layer.pad)
        h = jnp.maximum(h, 0.0)
        h = pool(h)
    return h.reshape(spec.batch, spec.fc_in)


def fc_forward(fc_w: jax.Array, fc_b: jax.Array, feat: jax.Array, *, oracle: bool = False) -> jax.Array:
    mmb = ref.matmul_bias if oracle else kmm.matmul_bias
    return mmb(feat, fc_w, fc_b)


def forward(spec: NetSpec, params: list[jax.Array], x: jax.Array, *, oracle: bool = False) -> jax.Array:
    """Full net -> class probabilities [B, n_classes]."""
    feat = conv_forward(spec, params[:-2], x, oracle=oracle)
    logits = fc_forward(params[-2], params[-1], feat, oracle=oracle)
    return ref.softmax(logits)


def loss_fn(spec: NetSpec, params: list[jax.Array], x: jax.Array, y1h: jax.Array, *, oracle: bool = False) -> jax.Array:
    feat = conv_forward(spec, params[:-2], x, oracle=oracle)
    logits = fc_forward(params[-2], params[-1], feat, oracle=oracle)
    return ref.softmax_xent(logits, y1h)


# ---------------------------------------------------------------------------
# Training steps (AdaGrad-β through the L1 update kernel)
# ---------------------------------------------------------------------------


def _apply_adagrad(params, accums, grads, *, oracle: bool = False):
    upd = ref.adagrad_update if oracle else kadagrad.adagrad_update
    new_p, new_a = [], []
    for p, a, g in zip(params, accums, grads):
        np_, na_ = upd(p, a, g, LR, BETA)
        new_p.append(np_)
        new_a.append(na_)
    return new_p, new_a


def train_step(spec: NetSpec, params, accums, x, y1h, *, oracle: bool = False):
    """One full SGD/AdaGrad step: the standalone Sukiyaki path (Table 4)."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(spec, ps, x, y1h, oracle=oracle))(list(params))
    new_p, new_a = _apply_adagrad(params, accums, grads, oracle=oracle)
    return new_p, new_a, loss


def grad_all(spec: NetSpec, params, x, y1h, *, oracle: bool = False):
    """Gradients of every parameter + loss: the MLitB client's work unit."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(spec, ps, x, y1h, oracle=oracle))(list(params))
    return grads, loss


def fc_step(spec: NetSpec, fc_w, fc_b, acc_w, acc_b, feat, y1h, *, oracle: bool = False):
    """The hybrid server's work unit: train the FC layer on a feature batch
    and emit the boundary cotangent dL/dfeat for the owning client."""

    def _loss(fw, fb, ft):
        return ref.softmax_xent(fc_forward(fw, fb, ft, oracle=oracle), y1h)

    loss, (gw, gb, dfeat) = jax.value_and_grad(_loss, argnums=(0, 1, 2))(fc_w, fc_b, feat)
    (nw, nb), (naw, nab) = _apply_adagrad([fc_w, fc_b], [acc_w, acc_b], [gw, gb], oracle=oracle)
    return nw, nb, naw, nab, dfeat, loss


def conv_grad(spec: NetSpec, conv_params, x, dfeat, *, oracle: bool = False):
    """The hybrid client's backward work unit: conv-stack gradients given
    the boundary cotangent.  Recomputes the forward pass (ships 320
    floats/sample instead of every activation — DESIGN.md §6.1)."""
    _, vjp = jax.vjp(lambda ps: conv_forward(spec, ps, x, oracle=oracle), list(conv_params))
    (grads,) = vjp(dfeat)
    return grads


# ---------------------------------------------------------------------------
# kNN (Table 2's workload) and smoke graph
# ---------------------------------------------------------------------------


def knn_chunk(q: jax.Array, t: jax.Array, *, oracle: bool = False):
    """Nearest neighbour of each query against one training chunk.

    q: [Q, D], t: [C, D] -> (min_dist2 [Q], argmin [Q] as f32).
    Distance matrix via the Pallas matmul: ||q-t||² = ||q||² - 2q·tᵀ + ||t||².
    The Rust coordinator folds (min, argmin) across chunk tickets.
    """
    mm = ref.matmul if oracle else kmm.matmul
    qq = (q * q).sum(axis=1, keepdims=True)  # [Q,1]
    tt = (t * t).sum(axis=1)[None, :]  # [1,C]
    d2 = qq + tt - 2.0 * mm(q, t.T)
    idx = jnp.argmin(d2, axis=1)
    return d2.min(axis=1), idx.astype(jnp.float32)


def smoke_matmul(a: jax.Array, b: jax.Array):
    """Tiny end-to-end artifact used by Rust runtime unit tests."""
    return kmm.matmul(a, b) + 2.0
