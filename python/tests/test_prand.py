"""Cross-language determinism: the SplitMix64 stream must match util::rng.

The known-answer constants here are duplicated in the Rust unit tests
(rust/src/util/rng.rs) — if either side drifts, golden validation breaks,
so both suites pin the same values.
"""

import numpy as np

from compile import prand


def test_splitmix64_known_answers():
    # Reference values for seed=0 (widely published SplitMix64 vectors).
    state, z = prand.splitmix64(0)
    assert z == 0xE220A8397B1DCDAF
    state, z = prand.splitmix64(state)
    assert z == 0x6E789E6AA1B965F4
    state, z = prand.splitmix64(state)
    assert z == 0x06C45D188009454F


def test_uniform_f32_deterministic():
    a = prand.uniform_f32(42, 16)
    b = prand.uniform_f32(42, 16)
    np.testing.assert_array_equal(a, b)
    c = prand.uniform_f32(43, 16)
    assert not np.array_equal(a, c)


def test_uniform_f32_range_and_spread():
    x = prand.uniform_f32(7, 4096)
    assert x.min() >= -1.0 and x.max() < 1.0
    assert abs(float(x.mean())) < 0.05
    assert x.std() > 0.5  # roughly uniform on [-1,1): sigma ~ 0.577


def test_uniform_f32_pinned_values_for_rust():
    # Pinned stream head for seed=1234: the Rust test asserts these exact
    # f32s from its own implementation.
    x = prand.uniform_f32(1234, 4)
    expected = [float(v) for v in x]
    assert len(set(expected)) == 4
    # Persist invariant: values are 24-bit-mantissa grid points in [-1,1).
    for v in expected:
        scaled = (v + 1.0) / 2.0 * (1 << 24)
        assert abs(scaled - round(scaled)) < 1e-6


def test_checksum_fields():
    c = prand.checksum(np.array([1.0, -2.0, 3.0], dtype=np.float32))
    assert c["len"] == 3
    assert c["sum"] == 2.0
    assert c["abs_sum"] == 6.0
    assert c["first"] == [1.0, -2.0, 3.0]
