"""AOT layer: the artifact registry is complete, coherent, and lowerable."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, prand

ARTS = {a.name: a for a in aot.build_artifacts()}

REQUIRED = [
    "smoke_matmul",
    "cifar_train_step",
    "cifar_forward",
    "cifar_grad",
    "cifar_conv_fwd",
    "cifar_conv_grad",
    "cifar_fc_step",
    "cifar_train_step_jnp",
    "mnist_train_step",
    "mnist_forward",
    "mnist_grad",
    "mnist_conv_fwd",
    "mnist_conv_grad",
    "mnist_fc_step",
    "knn_chunk",
    "knn_chunk_small",
    "adagrad_update",
]


def test_registry_complete():
    assert sorted(ARTS) == sorted(REQUIRED)


@pytest.mark.parametrize("name", REQUIRED)
def test_artifact_callable_with_declared_shapes(name):
    a = ARTS[name]
    inputs = [jnp.zeros(s.shape, jnp.float32) for s in a.input_specs]
    outs = a.fn(*inputs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    assert len(outs) == len(a.output_names), name


def test_train_step_io_symmetry():
    """params/accums appear in inputs and outputs in the same order —
    the Rust driver threads outputs straight back as next-step inputs."""
    for net in ("cifar", "mnist"):
        a = ARTS[f"{net}_train_step"]
        n = (len(a.input_names) - 2) // 2
        for i in range(n):
            assert a.output_names[i] == a.input_names[i] + "_new"
            assert a.input_specs[i].shape == a.input_specs[n + i].shape


def test_smoke_matmul_value():
    a = ARTS["smoke_matmul"]
    x = jnp.ones((8, 16))
    y = jnp.ones((16, 4))
    (out,) = (a.fn(x, y),) if not isinstance(a.fn(x, y), tuple) else (a.fn(x, y),)
    np.testing.assert_allclose(np.asarray(out).reshape(8, 4), 18.0 * np.ones((8, 4)), rtol=1e-6)


def test_lowering_produces_hlo_text():
    text = ARTS["smoke_matmul"].lower_hlo_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_golden_self_consistent():
    a = ARTS["adagrad_update"]
    g = a.golden(seed_base=99)
    assert list(g["outputs"]) == ["theta_new", "accum_new"]
    # Recompute from the recorded seeds and compare checksums.
    inputs = [jnp.asarray(prand.uniform_f32_array(s, sp.shape)) for s, sp in zip(g["input_seeds"], a.input_specs)]
    outs = a.fn(*inputs)
    for name, o in zip(a.output_names, outs):
        c = prand.checksum(np.asarray(o))
        assert abs(c["sum"] - g["outputs"][name]["sum"]) < 1e-3


def test_manifest_on_disk_matches_registry():
    man_path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as f:
        man = json.load(f)
    for name in REQUIRED:
        assert name in man["artifacts"], f"{name} missing from manifest — rerun make artifacts"
        entry = man["artifacts"][name]
        a = ARTS[name]
        assert [i["name"] for i in entry["inputs"]] == a.input_names
        assert [tuple(i["shape"]) for i in entry["inputs"]] == [tuple(s.shape) for s in a.input_specs]
    for net_name, net in model.NETS.items():
        m = man["nets"][net_name]
        assert m["param_names"] == net.param_names()
        assert m["batch"] == net.batch


def test_nets_manifest_shapes():
    nets = aot._nets_manifest()
    assert nets["cifar"]["fc_in"] == 320
    assert nets["mnist"]["input_hw"] == 28
    for net in nets.values():
        for name in net["param_names"]:
            assert name in net["param_shapes"]
