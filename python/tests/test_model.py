"""L2 correctness: model graphs, the hybrid split, and training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import CIFAR, MNIST


def small_net(batch=4):
    """A shrunken CIFAR-family net so tests stay fast on one core."""
    return model.NetSpec(
        name="tiny",
        input_hw=8,
        input_c=3,
        convs=(model.ConvLayer(5, 5, 3, 4, 2), model.ConvLayer(5, 5, 4, 6, 2)),
        fc_in=2 * 2 * 6,
        n_classes=5,
        batch=batch,
    )


def init_params(spec, seed=0, scale=0.3):
    shapes = spec.param_shapes()
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return [
        scale * jax.random.normal(k, shapes[n], dtype=jnp.float32)
        for k, n in zip(keys, spec.param_names())
    ]


def batch_for(spec, seed=1):
    x = jax.random.normal(jax.random.PRNGKey(seed), spec.x_shape, dtype=jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (spec.batch,), 0, spec.n_classes)
    y = jax.nn.one_hot(labels, spec.n_classes, dtype=jnp.float32)
    return x, y


# ---------------------------------------------------------------------------
# shapes & probability axioms
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [CIFAR, MNIST], ids=lambda s: s.name)
def test_param_shapes_consistent(spec):
    shapes = spec.param_shapes()
    assert shapes["fc_w"][0] == spec.fc_in
    # conv chain: each cout feeds the next cin; three pools divide hw by 8.
    for a, b in zip(spec.convs, spec.convs[1:]):
        assert a.cout == b.cin
    hw = spec.input_hw // (2 ** len(spec.convs))
    assert spec.fc_in == hw * hw * spec.convs[-1].cout


def test_cifar_matches_paper_fig2():
    # 32x32x16 -> 16x16x20 -> 8x8x20 feature maps, FC 320 -> 10.
    assert CIFAR.convs[0].cout == 16
    assert CIFAR.convs[1].cout == 20 and CIFAR.convs[2].cout == 20
    assert CIFAR.fc_in == 320 and CIFAR.n_classes == 10
    assert CIFAR.batch == 50  # the paper's mini-batch


def test_forward_is_distribution():
    spec = small_net()
    params = init_params(spec)
    x, _ = batch_for(spec)
    probs = model.forward(spec, params, x)
    assert probs.shape == (spec.batch, spec.n_classes)
    np.testing.assert_allclose(probs.sum(axis=1), np.ones(spec.batch), rtol=1e-5)
    assert (np.asarray(probs) >= 0).all()


def test_pallas_forward_matches_oracle_forward():
    spec = small_net()
    params = init_params(spec)
    x, _ = batch_for(spec)
    np.testing.assert_allclose(
        model.forward(spec, params, x),
        model.forward(spec, params, x, oracle=True),
        rtol=1e-4,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# the hybrid split (§4): fc_step + conv_grad must equal the full gradient
# ---------------------------------------------------------------------------


def test_hybrid_split_equals_full_gradient():
    """conv_grad(conv_params, x, dfeat-from-fc_step) == grad_all[:nconv].

    This is the invariant that makes the paper's algorithm *correct* at
    zero staleness: the split graphs compose to the full backward pass.
    """
    spec = small_net()
    params = init_params(spec)
    x, y = batch_for(spec)
    nconv = len(spec.conv_param_names())

    full_grads, full_loss = model.grad_all(spec, params, x, y)

    feat = model.conv_forward(spec, params[:nconv], x)
    *_, dfeat, loss = model.fc_step(spec, params[-2], params[-1], jnp.zeros_like(params[-2]), jnp.zeros_like(params[-1]), feat, y)
    conv_grads = model.conv_grad(spec, params[:nconv], x, dfeat)

    np.testing.assert_allclose(loss, full_loss, rtol=1e-5)
    for cg, fg in zip(conv_grads, full_grads[:nconv]):
        np.testing.assert_allclose(cg, fg, rtol=1e-3, atol=1e-4)


def test_fc_step_gradients_match_grad_all():
    spec = small_net()
    params = init_params(spec)
    x, y = batch_for(spec)
    nconv = len(spec.conv_param_names())
    full_grads, _ = model.grad_all(spec, params, x, y)
    feat = model.conv_forward(spec, params[:nconv], x)
    zw, zb = jnp.zeros_like(params[-2]), jnp.zeros_like(params[-1])
    nw, nb, naw, nab, _, _ = model.fc_step(spec, params[-2], params[-1], zw, zb, feat, y)
    # Recover the gradient from the AdaGrad update: acc' = acc + g².
    np.testing.assert_allclose(jnp.sqrt(naw), jnp.abs(full_grads[-2]), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(jnp.sqrt(nab), jnp.abs(full_grads[-1]), rtol=1e-3, atol=1e-4)


def test_train_step_equals_grad_plus_adagrad():
    spec = small_net()
    params = init_params(spec)
    accums = [jnp.zeros_like(p) for p in params]
    x, y = batch_for(spec)
    new_p, new_a, loss = model.train_step(spec, params, accums, x, y)
    grads, loss2 = model.grad_all(spec, params, x, y)
    np.testing.assert_allclose(loss, loss2, rtol=1e-6)
    from compile.kernels import ref as kref

    for p, a, g, np_, na_ in zip(params, accums, grads, new_p, new_a):
        rp, ra = kref.adagrad_update(p, a, g, model.LR, model.BETA)
        np.testing.assert_allclose(np_, rp, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(na_, ra, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# learning actually happens
# ---------------------------------------------------------------------------


def test_training_reduces_loss_on_learnable_batch():
    spec = small_net(batch=8)
    params = init_params(spec, scale=0.2)
    accums = [jnp.zeros_like(p) for p in params]
    # class-dependent means -> learnable
    labels = jnp.arange(8) % spec.n_classes
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(0), spec.x_shape) + labels[:, None, None, None] / 2.0
    y = jax.nn.one_hot(labels, spec.n_classes, dtype=jnp.float32)
    losses = []
    for _ in range(15):
        params, accums, loss = model.train_step(spec, params, accums, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_accumulators_monotone():
    spec = small_net()
    params = init_params(spec)
    accums = [jnp.zeros_like(p) for p in params]
    x, y = batch_for(spec)
    _, new_a, _ = model.train_step(spec, params, accums, x, y)
    for a in new_a:
        assert (np.asarray(a) >= 0).all()


# ---------------------------------------------------------------------------
# kNN graph (Table 2 workload)
# ---------------------------------------------------------------------------


def test_knn_chunk_matches_bruteforce():
    q = jax.random.normal(jax.random.PRNGKey(0), (7, 784))
    t = jax.random.normal(jax.random.PRNGKey(1), (50, 784))
    mind, argm = model.knn_chunk(q, t)
    d2 = ((q[:, None, :] - t[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(mind, d2.min(axis=1), rtol=1e-3, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(argm, dtype=np.int64), d2.argmin(axis=1))


def test_knn_chunk_self_query_is_zero():
    t = jax.random.normal(jax.random.PRNGKey(2), (20, 784))
    mind, argm = model.knn_chunk(t[:5], t)
    np.testing.assert_allclose(mind, np.zeros(5), atol=1e-2)
    np.testing.assert_array_equal(np.asarray(argm, dtype=np.int64), np.arange(5))
