"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the VJPs, since the gradient path also runs
through the kernels).  Tolerances are f32-accumulation-order tolerances,
not correctness slack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adagrad as kadagrad
from compile.kernels import conv as kconv
from compile.kernels import matmul as kmm
from compile.kernels import pool as kpool
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rnd(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 160),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    a, b = rnd(seed, (m, k)), rnd(seed + 1, (k, n))
    np.testing.assert_allclose(kmm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(m=st.integers(2, 64), k=st.integers(2, 96), n=st.integers(2, 24), seed=st.integers(0, 2**31 - 1))
def test_matmul_vjp_matches_ref(m, k, n, seed):
    a, b = rnd(seed, (m, k)), rnd(seed + 1, (k, n))
    g = rnd(seed + 2, (m, n))
    f = lambda a, b: (kmm.matmul(a, b) * g).sum()
    fr = lambda a, b: (ref.matmul(a, b) * g).sum()
    da, db = jax.grad(f, (0, 1))(a, b)
    ra, rb = jax.grad(fr, (0, 1))(a, b)
    np.testing.assert_allclose(da, ra, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(db, rb, rtol=1e-4, atol=1e-4)


def test_matmul_exact_block_multiple():
    a, b = rnd(0, (256, 128)), rnd(1, (128, 128))
    np.testing.assert_allclose(kmm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_forced_multiblock_tiling():
    # The production artifacts pick single-block tiles on CPU (budget
    # heuristic); force the full (M/bm, N/bn, K/bk) grid with the K-axis
    # accumulator here so the tiled path stays correctness-pinned.
    a, b = rnd(2, (200, 96)), rnd(3, (96, 40))
    out = kmm._matmul_impl(a, b, block_m=64, block_n=16, block_k=32)
    np.testing.assert_allclose(out, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_budget_heuristic_respects_budget():
    for m, k, n in [(51200, 75, 16), (1 << 17, 4096, 4096), (8, 8, 8)]:
        bm, bk, bn = kmm._pick_blocks(m, k, n, budget=16 * 1024 * 1024)
        assert 4 * (bm * bk + bk * bn + bm * bn) <= 16 * 1024 * 1024 or (bm, bk, bn) <= (128, 128, 128)
        assert bm % 8 == 0 and bk % 8 == 0 and bn % 8 == 0


def test_matmul_bias():
    a, b, bias = rnd(0, (33, 17)), rnd(1, (17, 9)), rnd(2, (9,))
    np.testing.assert_allclose(
        kmm.matmul_bias(a, b, bias), ref.matmul_bias(a, b, bias), rtol=1e-4, atol=1e-4
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        kmm.matmul(jnp.zeros((3, 4)), jnp.zeros((5, 6)))


# ---------------------------------------------------------------------------
# maxpool
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 16),
    w=st.integers(1, 16),
    c=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(b, h, w, c, seed):
    x = rnd(seed, (b, 2 * h, 2 * w, c))
    np.testing.assert_allclose(kpool.maxpool2(x), ref.maxpool2(x), rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(b=st.integers(1, 3), hw=st.integers(1, 8), c=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_maxpool_vjp_matches_ref(b, hw, c, seed):
    x = rnd(seed, (b, 2 * hw, 2 * hw, c))
    g = rnd(seed + 1, (b, hw, hw, c))
    gp = jax.grad(lambda x: (kpool.maxpool2(x) * g).sum())(x)
    gr = jax.grad(lambda x: (ref.maxpool2(x) * g).sum())(x)
    np.testing.assert_allclose(gp, gr, rtol=1e-5, atol=1e-5)


def test_maxpool_tie_splits_gradient():
    # A constant input ties everywhere; VJP must stay a linear transpose
    # (gradient split equally), not double-count.
    x = jnp.ones((1, 2, 2, 1))
    g = jax.grad(lambda x: kpool.maxpool2(x).sum())(x)
    np.testing.assert_allclose(g, 0.25 * jnp.ones_like(x), rtol=1e-6)


def test_maxpool_rejects_odd():
    with pytest.raises(AssertionError):
        kpool.maxpool2(jnp.zeros((1, 3, 4, 1)))


# ---------------------------------------------------------------------------
# adagrad
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 5000),
    lr=st.floats(1e-4, 1.0),
    beta=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_adagrad_matches_ref(n, lr, beta, seed):
    theta = rnd(seed, (n,))
    accum = jnp.abs(rnd(seed + 1, (n,)))
    grad = rnd(seed + 2, (n,))
    nt, na = kadagrad.adagrad_update(theta, accum, grad, lr, beta)
    rt, ra = ref.adagrad_update(theta, accum, grad, lr, beta)
    np.testing.assert_allclose(nt, rt, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(na, ra, rtol=1e-6, atol=1e-7)


def test_adagrad_beta_stabilises_first_step():
    # The paper's motivation: with zero accumulator and tiny gradients the
    # vanilla rule (beta=0) explodes; beta=1 keeps the step bounded by lr*|g|.
    theta = jnp.zeros((4,))
    accum = jnp.zeros((4,))
    grad = jnp.full((4,), 1e-6)
    nt, _ = kadagrad.adagrad_update(theta, accum, grad, 0.01, 1.0)
    assert jnp.abs(nt).max() < 1e-6  # bounded
    rt, _ = ref.adagrad_update(theta, accum, grad, 0.01, 0.0)
    assert jnp.abs(rt).max() > 1e-3  # vanilla step is ~lr regardless of |g|


def test_adagrad_multidim_shapes():
    theta = rnd(0, (7, 11, 3))
    accum = jnp.abs(rnd(1, (7, 11, 3)))
    grad = rnd(2, (7, 11, 3))
    nt, na = kadagrad.adagrad_update(theta, accum, grad, 0.05, 1.0)
    rt, ra = ref.adagrad_update(theta, accum, grad, 0.05, 1.0)
    assert nt.shape == theta.shape
    np.testing.assert_allclose(nt, rt, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(na, ra, rtol=1e-6)


# ---------------------------------------------------------------------------
# conv (im2col + matmul)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([6, 8, 12, 16]),
    cin=st.integers(1, 6),
    cout=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(b, hw, cin, cout, seed):
    x = rnd(seed, (b, hw, hw, cin))
    w = rnd(seed + 1, (25 * cin, cout), scale=0.2)
    bias = rnd(seed + 2, (cout,))
    np.testing.assert_allclose(
        kconv.conv2d(x, w, bias, 5, 5, 2), ref.conv2d(x, w, bias, 5, 5, 2), rtol=1e-3, atol=1e-3
    )


def test_im2col_layout_matches_ref():
    x = rnd(3, (2, 8, 8, 3))
    np.testing.assert_allclose(kconv.im2col(x, 5, 5, 2), ref.im2col(x, 5, 5, 2), rtol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv_vjp_matches_ref(seed):
    x = rnd(seed, (2, 8, 8, 3))
    w = rnd(seed + 1, (75, 4), scale=0.2)
    bias = rnd(seed + 2, (4,))

    def f(mod):
        return lambda x, w, b: (mod.conv2d(x, w, b, 5, 5, 2) ** 2).sum()

    gx, gw, gb = jax.grad(f(kconv), (0, 1, 2))(x, w, bias)
    rx, rw, rb = jax.grad(f(ref), (0, 1, 2))(x, w, bias)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw, rw, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gb, rb, rtol=1e-3, atol=1e-3)


def test_conv_3x3_kernel():
    x = rnd(0, (1, 6, 6, 2))
    w = rnd(1, (9 * 2, 5))
    bias = jnp.zeros((5,))
    np.testing.assert_allclose(
        kconv.conv2d(x, w, bias, 3, 3, 1), ref.conv2d(x, w, bias, 3, 3, 1), rtol=1e-4, atol=1e-4
    )
