"""Structural model of benches/store_throughput.rs.

Measures the same two dispatch cores as the Rust bench — the naive
full-scan store and the indexed scheduler — as pure-Python data
structures, under the same protocol (dispatch -> error-requeue cycles,
so the live-ticket count stays constant).  Absolute numbers are
Python-speed, not Rust-speed; the *ratio* between the two cores at each
pool size is the structural quantity this model exists to measure
(O(n) scan vs O(log n) index).  Regenerate native numbers with
`make bench-store` on a machine with cargo.

A second table models the WAL variants of rust/src/store/wal.rs: the
indexed core with one CRC-framed binary record appended per mutation,
under the three durability policies the Rust bench measures (os-cache =
write+flush only, group commit = fsync every 10 ms, fsync per record).
The structural quantity is the *relative* throughput vs wal-off — the
append is the same `[len][crc32][payload]` frame the Rust store writes,
and fsync cost is the real filesystem's, identical in both stacks.

A third table models the ISSUE 4 batched pipeline: dispatch+complete
drains at batch size k in {1, 4, 16, 64}, with one framed
DispatchBatch/CompleteBatch record per batch instead of one frame per
ticket, and — per the group-commit acknowledgement fix — one fsync per
*complete call*, so k divides the fsync count.  The fsync-bound rows
(group-ack, fsync-each) transfer directly; the wal-off row only shows
Python call overhead, not the Rust store's lock amortisation —
regenerate natively with `make bench-store`.

Usage: python bench_store_model.py [--quick]
"""

import heapq
import os
import struct
import sys
import tempfile
import time
import zlib

REQUEUE_AFTER_MS = 10**12
MIN_REDISTRIBUTE_MS = 10**12


def now_ms():
    return int(time.time() * 1000)


class NaiveModel:
    """One flat table; every dispatch scans all tickets, done included."""

    def __init__(self, n):
        t = now_ms()
        # [created_ms, status(0 pending/1 inflight/2 done), last_dist or None]
        self.tickets = [[t, 0, None] for _ in range(n)]

    def next_ticket(self, now):
        best = None
        best_key = None
        for tid, t in enumerate(self.tickets):  # the O(n) scan under the lock
            if t[1] == 2:
                continue
            vct = t[0] if t[2] is None else t[2] + REQUEUE_AFTER_MS
            if vct <= now:
                key = (vct, tid)
                if best_key is None or key < best_key:
                    best, best_key = tid, key
        if best is None:
            return None
        t = self.tickets[best]
        t[1] = 1
        t[2] = now
        return best

    def report_error(self, tid):
        t = self.tickets[tid]
        if t[1] == 1:
            t[1] = 0
            t[2] = None


class IndexedModel:
    """VCT-ordered ready index with lazy invalidation (heap standing in
    for the Rust BTreeSet; same O(log n) shape)."""

    def __init__(self, n):
        t = now_ms()
        self.meta = [[t, 0, None, 0] for _ in range(n)]  # created, status, last_dist, gen
        self.ready = [(t, tid, 0) for tid in range(n)]  # (vct, id, gen)
        heapq.heapify(self.ready)

    def _push(self, tid):
        m = self.meta[tid]
        vct = m[0] if m[2] is None else m[2] + REQUEUE_AFTER_MS
        heapq.heappush(self.ready, (vct, tid, m[3]))

    def next_ticket(self, now):
        while self.ready:
            vct, tid, gen = self.ready[0]
            m = self.meta[tid]
            if m[1] == 2 or gen != m[3]:  # evicted or stale entry
                heapq.heappop(self.ready)
                continue
            if vct > now:
                return None
            heapq.heappop(self.ready)
            m[1] = 1
            m[2] = now
            m[3] += 1
            # No in-flight re-push: this protocol error-requeues every
            # dispatch immediately (report_error pushes the live entry),
            # so a now+requeue entry would only accumulate as dead
            # weight the lazy deletion never reaches.
            return tid
        return None

    def report_error(self, tid):
        m = self.meta[tid]
        if m[1] == 1:
            m[1] = 0
            m[2] = None
            m[3] += 1
            self._push(tid)


class WalModel:
    """IndexedModel plus one framed, CRC'd log record per mutation —
    the same `[len u32][crc32 u32][payload]` layout as store/wal.rs.

    mode: "os"    -> write + flush per record, never fsync (OsOnly)
          "group" -> write + flush per record, fsync every 10 ms
          "fsync" -> write + flush + fsync per record (EveryRecord)
    """

    GROUP_COMMIT_S = 0.010

    def __init__(self, n, path, mode):
        self.inner = IndexedModel(n)
        self.f = open(path, "wb")
        self.mode = mode
        self.last_sync = time.perf_counter()

    def _append(self, op, tid, now):
        payload = struct.pack("<BQQ", op, tid, now)
        self.f.write(struct.pack("<II", len(payload), zlib.crc32(payload)) + payload)
        self.f.flush()
        if self.mode == "fsync":
            os.fsync(self.f.fileno())
        elif self.mode == "group":
            t = time.perf_counter()
            if t - self.last_sync >= self.GROUP_COMMIT_S:
                os.fsync(self.f.fileno())
                self.last_sync = t

    def next_ticket(self, now):
        tid = self.inner.next_ticket(now)
        if tid is not None:
            self._append(3, tid, now)  # OP_DISPATCH
        return tid

    def report_error(self, tid):
        self.inner.report_error(tid)
        self._append(5, tid, 0)  # OP_ERROR

    def close(self):
        self.f.close()


class BatchDrainModel:
    """Dispatch+complete drain at batch size k — the ISSUE 4 pipeline.

    One framed record per batch (DispatchBatch, then CompleteBatch with
    per-entry accepted flags), matching store/wal.rs.  mode:
      None        -> no log (wal-off)
      "os"        -> write+flush per record, never fsync
      "group-ack" -> write+flush per record, plus the acknowledgement
                     fix: one fsync per complete call (k amortises it)
      "fsync"     -> fsync per record (EveryRecord)
    """

    def __init__(self, n, path, mode):
        self.inner = IndexedModel(n)
        self.f = open(path, "wb") if mode else None
        self.mode = mode

    def _append(self, payload):
        self.f.write(struct.pack("<II", len(payload), zlib.crc32(payload)) + payload)
        self.f.flush()
        if self.mode == "fsync":
            os.fsync(self.f.fileno())

    def drain(self, k):
        """Dispatch+complete the whole pool in batches of k; returns
        (tickets, seconds).  k == 1 models the singular records."""
        t0 = time.perf_counter()
        done = 0
        while True:
            now = now_ms()
            batch = []
            for _ in range(k):
                tid = self.inner.next_ticket(now)
                if tid is None:
                    break
                batch.append(tid)
            if not batch:
                break
            if self.f:
                # OP_DISPATCH_BATCH / OP_DISPATCH payload shape.
                self._append(struct.pack("<BQI", 7 if k > 1 else 3, now, len(batch))
                             + struct.pack(f"<{len(batch)}Q", *batch))
            for tid in batch:
                self.inner.meta[tid][1] = 2  # done; lazy heap deletion
            if self.f:
                self._append(struct.pack("<BI", 8 if k > 1 else 4, len(batch))
                             + struct.pack("<" + "QB" * len(batch),
                                           *[x for tid in batch for x in (tid, 1)]))
                if self.mode == "group-ack":
                    os.fsync(self.f.fileno())  # Ack durability, once per batch
            done += len(batch)
        return done, time.perf_counter() - t0

    def close(self):
        if self.f:
            self.f.close()


def measure(store, window_s=1.0):
    t0 = time.perf_counter()
    ops = 0
    while time.perf_counter() - t0 < window_s:
        now = now_ms()
        tid = store.next_ticket(now)
        if tid is not None:
            store.report_error(tid)
            ops += 1
    return ops / (time.perf_counter() - t0)


def main():
    quick = "--quick" in sys.argv
    # Quick mode still covers 100k: that is the ISSUE 2 acceptance point.
    sizes = [1_000, 100_000] if quick else [1_000, 100_000, 1_000_000]
    print(f"{'live tickets':>12} {'naive t/s':>12} {'indexed t/s':>12} {'speedup':>9}")
    for n in sizes:
        naive = measure(NaiveModel(n))
        indexed = measure(IndexedModel(n))
        print(f"{n:>12} {naive:>12.0f} {indexed:>12.0f} {indexed / max(naive, 1e-9):>8.1f}x")

    # WAL overhead at the small pool (the index cost is flat; the append
    # and fsync costs are what this table isolates).
    n = 1_000
    print()
    print(f"{'variant':>12} {'t/s':>12} {'vs wal-off':>11}")
    baseline = measure(IndexedModel(n))
    print(f"{'wal-off':>12} {baseline:>12.0f} {'1.00x':>11}")
    with tempfile.TemporaryDirectory(prefix="sashimi-wal-model-") as d:
        for mode, label in [("os", "os-cache"), ("group", "group-10ms"), ("fsync", "fsync-each")]:
            store = WalModel(n, os.path.join(d, f"{mode}.log"), mode)
            tps = measure(store)
            store.close()
            print(f"{label:>12} {tps:>12.0f} {tps / max(baseline, 1e-9):>10.2f}x")

    # Batched pipeline sweep (ISSUE 4): dispatch+complete drains at
    # batch size k; one DispatchBatch/CompleteBatch frame per batch, and
    # (group-ack) one fsync per complete call.
    n = 20_000 if quick else 100_000
    print()
    print(f"{'backend':>12} {'k':>4} {'t/s':>12} {'vs k=1':>8}")
    with tempfile.TemporaryDirectory(prefix="sashimi-batch-model-") as d:
        for mode, label in [(None, "wal-off"), ("os", "os-cache"),
                            ("group-ack", "group-ack"), ("fsync", "fsync-each")]:
            # fsync-bound modes drain a smaller pool: the rate is the
            # quantity, and k=1 at ~300 fsyncs/s would take minutes.
            n_mode = n if mode in (None, "os") else max(2_000, n // 20)
            baseline = None
            for k in (1, 4, 16, 64):
                path = os.path.join(d, f"{label}-{k}.log")
                store = BatchDrainModel(n_mode, path, mode)
                done, secs = store.drain(k)
                store.close()
                assert done == n_mode, f"drain lost tickets: {done} != {n_mode}"
                tps = done / secs
                if baseline is None:
                    baseline = tps
                print(f"{label:>12} {k:>4} {tps:>12.0f} {tps / baseline:>7.1f}x")


if __name__ == "__main__":
    main()
