"""Structural model of benches/store_throughput.rs.

Measures the same two dispatch cores as the Rust bench — the naive
full-scan store and the indexed scheduler — as pure-Python data
structures, under the same protocol (dispatch -> error-requeue cycles,
so the live-ticket count stays constant).  Absolute numbers are
Python-speed, not Rust-speed; the *ratio* between the two cores at each
pool size is the structural quantity this model exists to measure
(O(n) scan vs O(log n) index).  Regenerate native numbers with
`make bench-store` on a machine with cargo.

A second table models the WAL variants of rust/src/store/wal.rs: the
indexed core with one CRC-framed binary record appended per mutation,
under the three durability policies the Rust bench measures (os-cache =
write+flush only, group commit = fsync every 10 ms, fsync per record).
The structural quantity is the *relative* throughput vs wal-off — the
append is the same `[len][crc32][payload]` frame the Rust store writes,
and fsync cost is the real filesystem's, identical in both stacks.

A third table models the ISSUE 4 batched pipeline: dispatch+complete
drains at batch size k in {1, 4, 16, 64}, with one framed
DispatchBatch/CompleteBatch record per batch instead of one frame per
ticket, and — per the group-commit acknowledgement fix — one fsync per
*complete call*, so k divides the fsync count.  The fsync-bound rows
(group-ack, fsync-each) transfer directly; the wal-off row only shows
Python call overhead, not the Rust store's lock amortisation —
regenerate natively with `make bench-store`.

A fourth table models the ISSUE 7 sharded dispatch core: clients x
dispatch shards, each shard a VCT heap under its own lock with
try-lock work-stealing, driven by real threads doing
next_tickets(16)/release_batch cycles.  The GIL serialises the heap
work itself, so the Python *throughput* column barely moves with the
shard count; the structural quantity that transfers is the home-lock
collision rate (how often a dispatching thread found its shard's
mutex already held), which the per-shard split drives toward zero —
natively that is the serialisation the >=4x acceptance floor removes.

Usage: python bench_store_model.py [--quick]
"""

import heapq
import os
import struct
import sys
import tempfile
import threading
import time
import zlib

REQUEUE_AFTER_MS = 10**12
MIN_REDISTRIBUTE_MS = 10**12


def now_ms():
    return int(time.time() * 1000)


class NaiveModel:
    """One flat table; every dispatch scans all tickets, done included."""

    def __init__(self, n):
        t = now_ms()
        # [created_ms, status(0 pending/1 inflight/2 done), last_dist or None]
        self.tickets = [[t, 0, None] for _ in range(n)]

    def next_ticket(self, now):
        best = None
        best_key = None
        for tid, t in enumerate(self.tickets):  # the O(n) scan under the lock
            if t[1] == 2:
                continue
            vct = t[0] if t[2] is None else t[2] + REQUEUE_AFTER_MS
            if vct <= now:
                key = (vct, tid)
                if best_key is None or key < best_key:
                    best, best_key = tid, key
        if best is None:
            return None
        t = self.tickets[best]
        t[1] = 1
        t[2] = now
        return best

    def report_error(self, tid):
        t = self.tickets[tid]
        if t[1] == 1:
            t[1] = 0
            t[2] = None


class IndexedModel:
    """VCT-ordered ready index with lazy invalidation (heap standing in
    for the Rust BTreeSet; same O(log n) shape)."""

    def __init__(self, n):
        t = now_ms()
        self.meta = [[t, 0, None, 0] for _ in range(n)]  # created, status, last_dist, gen
        self.ready = [(t, tid, 0) for tid in range(n)]  # (vct, id, gen)
        heapq.heapify(self.ready)

    def _push(self, tid):
        m = self.meta[tid]
        vct = m[0] if m[2] is None else m[2] + REQUEUE_AFTER_MS
        heapq.heappush(self.ready, (vct, tid, m[3]))

    def next_ticket(self, now):
        while self.ready:
            vct, tid, gen = self.ready[0]
            m = self.meta[tid]
            if m[1] == 2 or gen != m[3]:  # evicted or stale entry
                heapq.heappop(self.ready)
                continue
            if vct > now:
                return None
            heapq.heappop(self.ready)
            m[1] = 1
            m[2] = now
            m[3] += 1
            # No in-flight re-push: this protocol error-requeues every
            # dispatch immediately (report_error pushes the live entry),
            # so a now+requeue entry would only accumulate as dead
            # weight the lazy deletion never reaches.
            return tid
        return None

    def report_error(self, tid):
        m = self.meta[tid]
        if m[1] == 1:
            m[1] = 0
            m[2] = None
            m[3] += 1
            self._push(tid)


class WalModel:
    """IndexedModel plus one framed, CRC'd log record per mutation —
    the same `[len u32][crc32 u32][payload]` layout as store/wal.rs.

    mode: "os"    -> write + flush per record, never fsync (OsOnly)
          "group" -> write + flush per record, fsync every 10 ms
          "fsync" -> write + flush + fsync per record (EveryRecord)
    """

    GROUP_COMMIT_S = 0.010

    def __init__(self, n, path, mode):
        self.inner = IndexedModel(n)
        self.f = open(path, "wb")
        self.mode = mode
        self.last_sync = time.perf_counter()

    def _append(self, op, tid, now):
        payload = struct.pack("<BQQ", op, tid, now)
        self.f.write(struct.pack("<II", len(payload), zlib.crc32(payload)) + payload)
        self.f.flush()
        if self.mode == "fsync":
            os.fsync(self.f.fileno())
        elif self.mode == "group":
            t = time.perf_counter()
            if t - self.last_sync >= self.GROUP_COMMIT_S:
                os.fsync(self.f.fileno())
                self.last_sync = t

    def next_ticket(self, now):
        tid = self.inner.next_ticket(now)
        if tid is not None:
            self._append(3, tid, now)  # OP_DISPATCH
        return tid

    def report_error(self, tid):
        self.inner.report_error(tid)
        self._append(5, tid, 0)  # OP_ERROR

    def close(self):
        self.f.close()


class BatchDrainModel:
    """Dispatch+complete drain at batch size k — the ISSUE 4 pipeline.

    One framed record per batch (DispatchBatch, then CompleteBatch with
    per-entry accepted flags), matching store/wal.rs.  mode:
      None        -> no log (wal-off)
      "os"        -> write+flush per record, never fsync
      "group-ack" -> write+flush per record, plus the acknowledgement
                     fix: one fsync per complete call (k amortises it)
      "fsync"     -> fsync per record (EveryRecord)
    """

    def __init__(self, n, path, mode):
        self.inner = IndexedModel(n)
        self.f = open(path, "wb") if mode else None
        self.mode = mode

    def _append(self, payload):
        self.f.write(struct.pack("<II", len(payload), zlib.crc32(payload)) + payload)
        self.f.flush()
        if self.mode == "fsync":
            os.fsync(self.f.fileno())

    def drain(self, k):
        """Dispatch+complete the whole pool in batches of k; returns
        (tickets, seconds).  k == 1 models the singular records."""
        t0 = time.perf_counter()
        done = 0
        while True:
            now = now_ms()
            batch = []
            for _ in range(k):
                tid = self.inner.next_ticket(now)
                if tid is None:
                    break
                batch.append(tid)
            if not batch:
                break
            if self.f:
                # OP_DISPATCH_BATCH / OP_DISPATCH payload shape.
                self._append(struct.pack("<BQI", 7 if k > 1 else 3, now, len(batch))
                             + struct.pack(f"<{len(batch)}Q", *batch))
            for tid in batch:
                self.inner.meta[tid][1] = 2  # done; lazy heap deletion
            if self.f:
                self._append(struct.pack("<BI", 8 if k > 1 else 4, len(batch))
                             + struct.pack("<" + "QB" * len(batch),
                                           *[x for tid in batch for x in (tid, 1)]))
                if self.mode == "group-ack":
                    os.fsync(self.f.fileno())  # Ack durability, once per batch
            done += len(batch)
        return done, time.perf_counter() - t0

    def close(self):
        if self.f:
            self.f.close()


class ShardedModel:
    """S dispatch shards (S a power of two), each a VCT heap under its
    own lock, tickets routed by ``tid & (S - 1)`` — the PR 7 sharded
    core, with the same blocking-home / try-lock-sibling steal scan as
    rust/src/store/sched.rs."""

    def __init__(self, n, shards):
        t = now_ms()
        self.nshards = shards
        self.locks = [threading.Lock() for _ in range(shards)]
        self.meta = [[t, 0, None, 0] for _ in range(n)]  # created, status, last_dist, gen
        self.ready = [[] for _ in range(shards)]
        for tid in range(n):
            self.ready[tid & (shards - 1)].append((t, tid, 0))
        for h in self.ready:
            heapq.heapify(h)
        # Counter updates are read-modify-write races between threads,
        # but the GIL makes `+=` on an int close enough for a model.
        self.collisions = 0
        self.steals = 0

    def _pop_from(self, shard, now, k):
        """Caller holds locks[shard].  Same lazy invalidation as
        IndexedModel, per shard."""
        out = []
        heap = self.ready[shard]
        while heap and len(out) < k:
            vct, tid, gen = heap[0]
            m = self.meta[tid]
            if m[1] == 2 or gen != m[3]:
                heapq.heappop(heap)
                continue
            if vct > now:
                break
            heapq.heappop(heap)
            m[1] = 1
            m[2] = now
            m[3] += 1
            out.append(tid)
        return out

    def next_tickets(self, client, now, k):
        home = hash(client) & (self.nshards - 1)
        out = []
        for i in range(self.nshards):
            if len(out) >= k:
                break
            shard = (home + i) % self.nshards
            lock = self.locks[shard]
            if i == 0:
                if not lock.acquire(blocking=False):
                    self.collisions += 1  # home mutex was held: the contention
                    lock.acquire()  # ...the 1-shard config serialises on
            elif not lock.acquire(blocking=False):
                continue  # steal never blocks
            try:
                got = self._pop_from(shard, now, k - len(out))
            finally:
                lock.release()
            if got and i > 0:
                self.steals += 1
            out.extend(got)
        return out

    def release_batch(self, tids):
        by_shard = {}
        for tid in tids:
            by_shard.setdefault(tid & (self.nshards - 1), []).append(tid)
        for shard, ids in sorted(by_shard.items()):
            with self.locks[shard]:
                for tid in ids:
                    m = self.meta[tid]
                    if m[1] == 1:
                        m[1] = 0
                        m[2] = None
                        m[3] += 1
                        heapq.heappush(self.ready[shard], (m[0], tid, m[3]))


def measure_sharded(store, clients, window_s=0.7):
    """`clients` threads each run next_tickets(16) -> release_batch
    cycles for the window; returns tickets dispatched per second."""
    stop = [False]
    counts = [0] * clients

    def run(w):
        name = f"c{w}"
        while not stop[0]:
            batch = store.next_tickets(name, now_ms(), 16)
            if batch:
                store.release_batch(batch)
                counts[w] += len(batch)

    threads = [threading.Thread(target=run, args=(w,)) for w in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(window_s)
    stop[0] = True
    for t in threads:
        t.join()
    return sum(counts) / (time.perf_counter() - t0)


def measure(store, window_s=1.0):
    t0 = time.perf_counter()
    ops = 0
    while time.perf_counter() - t0 < window_s:
        now = now_ms()
        tid = store.next_ticket(now)
        if tid is not None:
            store.report_error(tid)
            ops += 1
    return ops / (time.perf_counter() - t0)


def main():
    quick = "--quick" in sys.argv
    # Quick mode still covers 100k: that is the ISSUE 2 acceptance point.
    sizes = [1_000, 100_000] if quick else [1_000, 100_000, 1_000_000]
    print(f"{'live tickets':>12} {'naive t/s':>12} {'indexed t/s':>12} {'speedup':>9}")
    for n in sizes:
        naive = measure(NaiveModel(n))
        indexed = measure(IndexedModel(n))
        print(f"{n:>12} {naive:>12.0f} {indexed:>12.0f} {indexed / max(naive, 1e-9):>8.1f}x")

    # WAL overhead at the small pool (the index cost is flat; the append
    # and fsync costs are what this table isolates).
    n = 1_000
    print()
    print(f"{'variant':>12} {'t/s':>12} {'vs wal-off':>11}")
    baseline = measure(IndexedModel(n))
    print(f"{'wal-off':>12} {baseline:>12.0f} {'1.00x':>11}")
    with tempfile.TemporaryDirectory(prefix="sashimi-wal-model-") as d:
        for mode, label in [("os", "os-cache"), ("group", "group-10ms"), ("fsync", "fsync-each")]:
            store = WalModel(n, os.path.join(d, f"{mode}.log"), mode)
            tps = measure(store)
            store.close()
            print(f"{label:>12} {tps:>12.0f} {tps / max(baseline, 1e-9):>10.2f}x")

    # Batched pipeline sweep (ISSUE 4): dispatch+complete drains at
    # batch size k; one DispatchBatch/CompleteBatch frame per batch, and
    # (group-ack) one fsync per complete call.
    n = 20_000 if quick else 100_000
    print()
    print(f"{'backend':>12} {'k':>4} {'t/s':>12} {'vs k=1':>8}")
    with tempfile.TemporaryDirectory(prefix="sashimi-batch-model-") as d:
        for mode, label in [(None, "wal-off"), ("os", "os-cache"),
                            ("group-ack", "group-ack"), ("fsync", "fsync-each")]:
            # fsync-bound modes drain a smaller pool: the rate is the
            # quantity, and k=1 at ~300 fsyncs/s would take minutes.
            n_mode = n if mode in (None, "os") else max(2_000, n // 20)
            baseline = None
            for k in (1, 4, 16, 64):
                path = os.path.join(d, f"{label}-{k}.log")
                store = BatchDrainModel(n_mode, path, mode)
                done, secs = store.drain(k)
                store.close()
                assert done == n_mode, f"drain lost tickets: {done} != {n_mode}"
                tps = done / secs
                if baseline is None:
                    baseline = tps
                print(f"{label:>12} {k:>4} {tps:>12.0f} {tps / baseline:>7.1f}x")

    # Sharded dispatch contention sweep (ISSUE 7).  Throughput is
    # GIL-bound in Python; the collision column is the structural
    # quantity (see module docstring).
    n = 20_000 if quick else 100_000
    client_counts = [1, 4, 16] if quick else [1, 2, 4, 8, 16]
    print()
    print(f"{'clients':>8} {'shards':>7} {'t/s':>12} {'collisions':>11} {'steals':>7}")
    for clients in client_counts:
        for shards in (1, 4, 16):
            store = ShardedModel(n, shards)
            tps = measure_sharded(store, clients)
            print(f"{clients:>8} {shards:>7} {tps:>12.0f} "
                  f"{store.collisions:>11} {store.steals:>7}")


if __name__ == "__main__":
    main()
